//! Algorithm 3 (paper Fig. 9): fully associative 256-bin histogram.
//!
//! One sample per RCAM row. For each bin: a single compare of the bin
//! index against bits [31..24] of the sample (the CAM's native one-cycle
//! content match), then the reduction tree counts the tagged rows. Two
//! operations per bin, independent of the number of samples.

use crate::algorithms::kernel::{
    one_shot_out, sharded, Kernel, KernelEntry, QueryOut, Resident, ResidentDyn, ShardMerge,
    Sharded,
};
use crate::controller::{Controller, ExecStats};
use crate::error::{ensure, Result};
use crate::host::rack::PrinsRack;
use crate::isa::{Field, Instr, Program, RowLayout};
use crate::rcam::shard::{merge_histograms, ShardPlan};
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};
use crate::workloads::synth_hist_samples;
use std::ops::Range;

/// Number of histogram bins (the paper's fixed 256-bin kernel).
pub const BINS: usize = 256;

/// Loaded histogram dataset + the per-bin compare/reduce program.
///
/// Load-once / query-many: [`HistogramKernel::load`] writes the samples
/// once (charged, [`HistogramKernel::load_stats`]); queries are
/// compare-only — [`HistogramKernel::query_at`] re-bins the resident
/// samples on any 8-bit window (new bin edges) without a single write,
/// so repeat queries leave storage and wear untouched.
pub struct HistogramKernel {
    /// Number of loaded samples.
    pub n: usize,
    sample: Field,
    /// dataset-membership flag: unloaded (all-zero) rows of the array must
    /// not be counted in bin 0 (paper §5.1: data elements are identified
    /// associatively, so membership is part of the compare pattern)
    valid: Field,
    ds: Dataset,
    load_stats: ExecStats,
}

/// Result of one histogram run.
pub struct HistResult {
    /// The 256 bin counts.
    pub hist: Vec<u64>,
    /// Execution statistics of the run.
    pub stats: ExecStats,
}

impl HistogramKernel {
    /// Allocate rows and load the samples (one sample per row, plus the
    /// dataset-membership valid bit). Two charged row writes per sample
    /// (32-bit value + valid bit).
    pub fn load(sm: &mut StorageManager, array: &mut PrinsArray, x: &[u32]) -> Self {
        let mut layout = RowLayout::new(array.width() as u16);
        let sample = layout.alloc("sample", 32);
        let valid = layout.alloc("valid", 1);
        let ds = sm.alloc(x.len(), layout).expect("storage full");
        let (c0, l0) = (array.cycles, array.ledger());
        for (i, &v) in x.iter().enumerate() {
            array.load_row_bits_charged(ds.rows.start + i, sample.base as usize, 32, v as u64);
            array.load_row_bits_charged(ds.rows.start + i, valid.base as usize, 1, 1);
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        HistogramKernel {
            n: x.len(),
            sample,
            valid,
            ds,
            load_stats,
        }
    }

    /// Device-model cost of the load phase (paid once per dataset).
    pub fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    /// The full histogram program over the paper's fixed bin edges
    /// (bits \[31..24\]): [`HistogramKernel::program_at`] with `lo_bit`
    /// = 24.
    pub fn program(&self) -> Program {
        self.program_at(24)
    }

    /// The per-bin compare/reduce program (Fig. 9) binning on sample bits
    /// `[lo_bit + 7 .. lo_bit]` — re-binnable edges for resident
    /// datasets: a different `lo_bit` is a brand-new 256-bin histogram of
    /// the same stored samples, still two operations per bin and zero
    /// writes. Panics on an out-of-window `lo_bit`; fallible callers use
    /// [`HistogramKernel::try_program_at`].
    pub fn program_at(&self, lo_bit: u16) -> Program {
        self.try_program_at(lo_bit).expect("invalid bin window")
    }

    /// Fallible twin of [`HistogramKernel::program_at`]: a `lo_bit`
    /// whose bin window leaves the 32-bit sample field — which would
    /// place bin compare columns at or past the array width, a W01
    /// violation — returns a clean `Err` and synthesizes nothing.
    ///
    /// The window check runs in u32: the old u16 `lo_bit + 8 <= 32`
    /// guard wrapped for `lo_bit ≥ 65528` (panic in debug, silently
    /// *passing* the guard in release), so e.g. `lo_bit = 65535` would
    /// emit a wrapped program instead of failing.
    pub fn try_program_at(&self, lo_bit: u16) -> Result<Program> {
        ensure!(
            lo_bit as u32 + 8 <= 32,
            "bin window [{}..={}] leaves the 32-bit sample field (bin columns would land at or past the array width)",
            lo_bit,
            lo_bit as u32 + 7
        );
        let mut prog = Program::new();
        let byte = self.sample.slice(lo_bit, 8);
        for bin in 0..BINS as u64 {
            let mut pat = byte.pattern(bin); // line 3
            pat.push((self.valid.base, true));
            prog.push(Instr::Compare(pat));
            prog.push(Instr::ReduceCount); // line 4: H_bin ← Reduction(tags)
        }
        Ok(prog)
    }

    /// One-shot alias for [`HistogramKernel::query`], kept for the
    /// load-and-run-once callers (CLI, figures, examples).
    pub fn run(&self, ctl: &mut Controller) -> HistResult {
        self.query(ctl)
    }

    /// Query phase over the default bin edges (bits \[31..24\]).
    pub fn query(&self, ctl: &mut Controller) -> HistResult {
        self.query_at(ctl, 24)
    }

    /// Query phase: execute the 256-bin program binning on bits
    /// `[lo_bit + 7 .. lo_bit]` of the resident samples and read the
    /// counts back. Compare-only — charges zero writes, so wear is
    /// untouched no matter how many queries run. Panics on an
    /// out-of-window `lo_bit`; fallible callers use
    /// [`HistogramKernel::try_query_at`].
    pub fn query_at(&self, ctl: &mut Controller, lo_bit: u16) -> HistResult {
        self.try_query_at(ctl, lo_bit).expect("invalid bin window")
    }

    /// Fallible twin of [`HistogramKernel::query_at`]: an out-of-window
    /// `lo_bit` returns a clean `Err` **before** the stats window opens —
    /// no cycles charged, no array state touched.
    pub fn try_query_at(&self, ctl: &mut Controller, lo_bit: u16) -> Result<HistResult> {
        let prog = self.try_program_at(lo_bit)?;
        Ok(self.query_program(ctl, &prog))
    }

    /// Execute one already-synthesized bin-sweep program and collect the
    /// counts. Shared by the fresh and cached query paths, so the two
    /// are bit-identical by construction.
    fn query_program(&self, ctl: &mut Controller, prog: &Program) -> HistResult {
        ctl.begin_stats();
        let hist = ctl.execute_collect(prog);
        // one pipelined tree-drain latency at the end of the bin sweep
        ctl.array.charge_reduction_latency();
        let mut stats = ctl.stats();
        stats.passes = 0; // no writes in this kernel
        HistResult { hist, stats }
    }

    /// Analytic cycle cost of one query — the per-repetition floor of a
    /// resident dataset: 2 issue cycles per bin plus `array`'s pipelined
    /// reduction-tree drain. Exact for every `lo_bit`.
    pub fn query_floor_cycles(&self, array: &PrinsArray) -> u64 {
        self.program().cycle_estimate() + array.reduction_latency_cycles()
    }

    /// The storage allocation backing this kernel's samples.
    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }
}

impl Kernel for HistogramKernel {
    type Data = [u32];
    type Params = u16; // lo_bit of the 8-bit bin window
    type Output = Vec<u64>;

    const NAME: &'static str = "hist";
    const VERB: &'static str = "HIST";
    const QUERY_ARITY: usize = 0;
    // query_at is exactly "execute program_at + tree drain, passes = 0",
    // and the output is the collected ReduceCount vector verbatim — the
    // shared-read contract (Kernel::SHARED_READ doc).
    const SHARED_READ: bool = true;

    fn data_rows(data: &[u32]) -> usize {
        data.len()
    }

    fn width(_data: &[u32]) -> usize {
        40
    }

    fn load_range(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        data: &[u32],
        range: Range<usize>,
    ) -> Self {
        HistogramKernel::load(sm, array, &data[range])
    }

    fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    fn load_payload_bytes(&self) -> u64 {
        4 * self.n as u64
    }

    fn load_writes(&self) -> u64 {
        2 * self.n as u64 // sample value + valid bit per row
    }

    fn resident_columns(&self) -> Range<u16> {
        // sample field plus the valid bit — the whole stored row
        self.sample.base..(self.valid.base + self.valid.width)
    }

    fn query_shard(
        &self,
        ctl: &mut Controller,
        _sm: &StorageManager,
        _range: &Range<usize>,
        params: &u16,
    ) -> (Vec<u64>, ExecStats) {
        let res = self.query_at(ctl, *params);
        (res.hist, res.stats)
    }

    fn query_msg_bytes(&self, _range: &Range<usize>, _params: &u16) -> (u64, u64) {
        (0, (BINS * 8) as u64) // bare command down, 256 bins back
    }

    fn query_floor_cycles(&self, array: &PrinsArray, _params: &u16) -> u64 {
        // the inherent floor; exact for every lo_bit (the program's
        // shape is window-independent)
        self.query_floor_cycles(array)
    }

    fn query_plan(&self, array: &PrinsArray, params: &u16) -> crate::analysis::QueryPlan {
        crate::analysis::QueryPlan {
            programs: vec![self.program_at(*params)],
            // the final pipelined tree drain charged by query_at
            extra_cycles: array.reduction_latency_cycles(),
        }
    }

    fn shared_output(&self, _params: &u16, collected: Vec<u64>) -> Option<Vec<u64>> {
        Some(collected) // one ReduceCount per bin, already in bin order
    }

    fn params_key(&self, params: &u16) -> Option<String> {
        // the plan depends only on the bin window position
        Some(params.to_string())
    }

    fn query_shard_planned(
        &self,
        ctl: &mut Controller,
        _sm: &StorageManager,
        _range: &Range<usize>,
        _params: &u16,
        plan: &crate::analysis::QueryPlan,
    ) -> Option<(Vec<u64>, ExecStats)> {
        let res = self.query_program(ctl, &plan.programs[0]);
        Some((res.hist, res.stats))
    }

    fn parse_params(&self, _args: &[&str]) -> Result<u16> {
        Ok(24) // the wire form queries the paper's fixed bin edges
    }

    fn seeded_params(&self, q: usize, _seed: u64) -> u16 {
        [24u16, 16, 8, 0][q % 4] // rotate the bin window per query
    }
}

impl ShardMerge for HistogramKernel {
    type Merged = Vec<u64>;

    fn merge(outputs: Vec<Vec<u64>>, _plan: &ShardPlan, _params: &u16) -> Vec<u64> {
        merge_histograms(&outputs)
    }

    fn fields(merged: &Vec<u64>) -> String {
        let top = merged.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        let total: u64 = merged.iter().sum();
        format!("top_bin={top} total={total}")
    }

    fn bits(merged: &Vec<u64>) -> Vec<u64> {
        merged.clone()
    }
}

fn load_args(rack: &PrinsRack, args: &[&str]) -> Result<Box<dyn ResidentDyn>> {
    let [n, seed] = args else {
        crate::error::bail!("usage: LOAD HIST n seed");
    };
    let (n, seed): (usize, u64) = (n.parse()?, seed.parse()?);
    ensure!(n > 0 && n <= 1 << 20, "n out of range");
    let xs = synth_hist_samples(n, seed);
    Ok(Box::new(Resident::<HistogramKernel>::load(rack, &xs)))
}

fn synth_load(rack: &PrinsRack, n: usize, _dims: usize, seed: u64) -> Box<dyn ResidentDyn> {
    Box::new(Resident::<HistogramKernel>::load(
        rack,
        &synth_hist_samples(n, seed),
    ))
}

fn one_shot(rack: &PrinsRack, args: &[&str]) -> Result<QueryOut> {
    let [n, seed] = args else {
        crate::error::bail!("usage: HIST n seed");
    };
    let (n, seed): (usize, u64) = (n.parse()?, seed.parse()?);
    ensure!(n > 0 && n <= 1 << 20, "n out of range");
    let xs = synth_hist_samples(n, seed);
    Ok(one_shot_out::<HistogramKernel>(rack, &xs, &24))
}

/// The histogram kernel's registry entry.
pub const ENTRY: KernelEntry = KernelEntry {
    name: HistogramKernel::NAME,
    verb: HistogramKernel::VERB,
    query_arity: HistogramKernel::QUERY_ARITY,
    one_shot_arity: 2,
    load_usage: "LOAD HIST n seed",
    query_usage: "HIST id",
    one_shot_usage: "HIST n seed",
    dense: false,
    write_free_queries: true,
    overlay_queries: true,
    coalesce_queries: false,
    bits_f32: false,
    flops: |n, _dims| 2.0 * n as f64,
    load: load_args,
    synth_load,
    one_shot,
};

/// Deprecated pre-framework name for [`Resident<HistogramKernel>`].
#[deprecated(note = "use Resident<HistogramKernel> (algorithms::kernel)")]
pub type ResidentHistogram = Resident<HistogramKernel>;

/// Rack-sharded histogram over the default bin edges, one-shot — a thin
/// wrapper over the generic framework ([`sharded`]); the merged bins are
/// on `.merged`.
pub fn histogram_sharded(rack: &PrinsRack, x: &[u32]) -> Sharded<HistogramKernel> {
    sharded::<HistogramKernel>(rack, x, &24)
}

/// Scalar CPU baseline over the default bin edges (bits \[31..24\]).
pub fn histogram_baseline(x: &[u32]) -> Vec<u64> {
    histogram_baseline_at(x, 24)
}

/// Scalar CPU baseline binning on bits `[lo_bit + 7 .. lo_bit]` (the
/// re-binnable-edges twin of [`HistogramKernel::query_at`]).
pub fn histogram_baseline_at(x: &[u32], lo_bit: u16) -> Vec<u64> {
    let mut h = vec![0u64; BINS];
    for &v in x {
        h[((v >> lo_bit) & 0xFF) as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{synth_hist_samples, Rng};

    #[test]
    fn histogram_matches_baseline() {
        let xs = synth_hist_samples(5000, 17);
        let mut array = PrinsArray::single(xs.len(), 40);
        let mut sm = StorageManager::new(xs.len());
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl);
        assert_eq!(res.hist, histogram_baseline(&xs));
        assert_eq!(res.hist.iter().sum::<u64>(), xs.len() as u64);
    }

    #[test]
    fn two_ops_per_bin() {
        // paper: compare + reduction per bin — 2 issue cycles per bin plus
        // the final pipelined tree drain
        let xs: Vec<u32> = (0..64).collect();
        let mut array = PrinsArray::single(64, 40);
        let mut sm = StorageManager::new(64);
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl);
        let drain = ctl.array.reduction_latency_cycles();
        assert_eq!(res.stats.cycles, 2 * BINS as u64 + drain);
    }

    #[test]
    fn sharded_histogram_merges_binwise() {
        let xs = synth_hist_samples(3000, 23);
        let rack = PrinsRack::new(3);
        let res = histogram_sharded(&rack, &xs);
        assert_eq!(res.merged, histogram_baseline(&xs));
        assert_eq!(res.rack.shards, 3);
        assert_eq!(res.rack.link_messages, 6);
        assert!(res.rack.total_cycles > res.rack.max_shard_cycles);
    }

    #[test]
    fn rebinned_queries_match_shifted_baselines() {
        let xs = synth_hist_samples(2000, 31);
        let mut array = PrinsArray::single(xs.len(), 40);
        let mut sm = StorageManager::new(xs.len());
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        assert_eq!(kern.load_stats().ledger.n_write, 2 * xs.len() as u64);
        let mut ctl = Controller::new(array);
        for lo in [24u16, 16, 8, 0] {
            let res = kern.query_at(&mut ctl, lo);
            assert_eq!(res.hist, histogram_baseline_at(&xs, lo), "lo_bit={lo}");
            assert_eq!(res.stats.cycles, kern.query_floor_cycles(&ctl.array));
            assert_eq!(res.stats.ledger.n_write, 0, "queries never write");
        }
        // resident rack path agrees bin-for-bin
        let rack = PrinsRack::new(3);
        let mut res = Resident::<HistogramKernel>::load(&rack, &xs);
        for lo in [24u16, 8] {
            assert_eq!(res.query(&lo).merged, histogram_baseline_at(&xs, lo));
        }
    }

    /// Satellite regression (ISSUE 9): out-of-window `lo_bit` must be a
    /// clean `Err`, never a wrapped/truncated program — including the
    /// u16-wrap zone `lo_bit ≥ 65528` where the old `lo_bit + 8 <= 32`
    /// guard silently passed in release builds. Anchored to W01: the
    /// program the wrapped guard would have emitted references bin
    /// columns at/past the array width, which the static analyzer flags,
    /// while every accepted window stays W01-clean.
    #[test]
    fn out_of_window_rebins_err_cleanly_and_are_w01_anchored() {
        use crate::analysis::{check_program, ArrayShape, RuleId};
        let xs = synth_hist_samples(64, 3);
        let mut array = PrinsArray::single(xs.len(), 40);
        let mut sm = StorageManager::new(xs.len());
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let shape = ArrayShape::of(&ctl.array);
        // every accepted window synthesizes a W01-clean program
        for lo in [0u16, 8, 16, 24] {
            let prog = kern.try_program_at(lo).expect("in-window lo_bit");
            assert!(
                check_program(&prog, &shape).is_empty(),
                "lo_bit={lo}: accepted window must verify clean"
            );
        }
        // out-of-window lo_bits err cleanly — no panic, no program, and
        // try_query_at charges nothing before refusing
        let c0 = ctl.array.cycles;
        for lo in [25u16, 32, 33, 40, 255, 65527, 65528, 65535] {
            assert!(kern.try_program_at(lo).is_err(), "lo_bit={lo}");
            assert!(kern.try_query_at(&mut ctl, lo).is_err(), "lo_bit={lo}");
        }
        assert_eq!(ctl.array.cycles, c0, "a refused re-bin must charge nothing");
        // the W01 anchor: a compare over the columns lo_bit = 33 would
        // have produced (bins land at cols 33..=40 on this 40-col
        // layout) is exactly an out-of-bounds-column diagnostic
        let mut wrapped = Program::new();
        wrapped.push(Instr::Compare((33u16..41).map(|b| (b, false)).collect()));
        assert!(
            check_program(&wrapped, &shape)
                .iter()
                .any(|d| d.rule == RuleId::W01),
            "the guarded-against program must be a W01 violation"
        );
    }

    #[test]
    fn cycles_independent_of_sample_count() {
        let run_n = |n: usize| {
            let mut rng = Rng::seed_from(4);
            let xs: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let mut array = PrinsArray::single(n, 40);
            let mut sm = StorageManager::new(n);
            let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
            let mut ctl = Controller::new(array);
            // subtract the N-dependent tree drain to compare issue cycles
            kern.run(&mut ctl).stats.cycles - ctl.array.reduction_latency_cycles()
        };
        assert_eq!(run_n(64), run_n(4096));
    }
}
