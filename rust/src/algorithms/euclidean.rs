//! Algorithm 1 (paper Fig. 7): fully associative Euclidean distance.
//!
//! Samples live one-attribute-set-per-row (a sample's D attributes occupy
//! one row's data fields). For every cluster center: broadcast the center
//! coordinates to all rows (a single tagged write per attribute — the
//! CAM broadcast), then per attribute compute dist = x − c, square it,
//! and accumulate — all in fp32 microcode, all rows in parallel. The
//! cycle count is independent of the number of samples, which is the
//! paper's headline property.

use crate::algorithms::kernel::{
    one_shot_out, sharded, FloatMatrix, Kernel, KernelEntry, QueryOut, Resident, ResidentDyn,
    ShardMerge, Sharded,
};
use crate::controller::{Controller, ExecStats};
use crate::error::{ensure, Result};
use crate::host::rack::PrinsRack;
use crate::isa::{Field, Program, RowLayout};
use crate::micro::float::{bits_to_f32, unpacked_bits, FloatField, FpScratch, FP_SCRATCH_BITS};
use crate::micro::{self};
use crate::rcam::shard::{local_topk, merge_concat, merge_topk, ShardPlan};
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};
use crate::workloads::{synth_samples, synth_uniform};
use std::ops::Range;

/// Row layout: D attribute slots + center copy + work area.
/// 33 bits per unpacked fp32; W must fit x, c, diff, acc + scratch.
pub struct EuclideanLayout {
    /// Attributes per sample.
    pub dims: usize,
    /// The D stored attribute fields (unpacked fp32).
    pub x: Vec<FloatField>,
    /// Broadcast slot for the current center coordinate.
    pub c: FloatField,
    /// Difference work area (`x_j − c`).
    pub diff: FloatField,
    /// Squared-difference work area.
    pub sq: FloatField,
    /// Running squared-distance accumulator.
    pub acc: FloatField,
    /// Operand copy used by the fp-sub swap step.
    pub ycopy: FloatField,
    /// fp-add/sub scratch flags/fields.
    pub scratch: FpScratch,
    /// Working exponent field of the fp alignment step.
    pub wexp: Field,
    /// Base column of the fp-mul scratch area.
    pub mul_scratch: u16,
    /// Total columns the layout occupies.
    pub width: u16,
}

impl EuclideanLayout {
    /// Columns: D×33 attributes | c | diff | sq | acc | ycopy | scratch.
    pub fn new(dims: usize) -> Self {
        let mut base = 0u16;
        let mut next = |w: u16| {
            let b = base;
            base += w;
            b
        };
        let x: Vec<FloatField> = (0..dims).map(|_| FloatField::at(next(33))).collect();
        let c = FloatField::at(next(33));
        let diff = FloatField::at(next(33));
        let sq = FloatField::at(next(33));
        let acc = FloatField::at(next(33));
        let ycopy = FloatField::at(next(33));
        let scratch = FpScratch::at(next(FP_SCRATCH_BITS));
        let wexp = Field::new(next(8), 8);
        let mul_scratch = next(crate::micro::float::FP_MUL_SCRATCH_BITS);
        EuclideanLayout {
            dims,
            x,
            c,
            diff,
            sq,
            acc,
            ycopy,
            scratch,
            wexp,
            mul_scratch,
            width: base,
        }
    }

    /// The storage-manager row layout for this kernel (≥ 256-bit rows).
    pub fn row_layout(&self) -> RowLayout {
        RowLayout::new(self.width.max(256))
    }
}

/// Result of one ED run: per-sample squared distance to each center +
/// execution stats.
pub struct EdResult {
    /// dists\[center\]\[sample\]
    pub dists: Vec<Vec<f32>>,
    /// Execution statistics of the run.
    pub stats: ExecStats,
}

/// Loaded ED dataset + per-center program generator.
///
/// The **load phase** ([`EuclideanKernel::load`]) writes the samples into
/// RCAM rows once and is charged to the device model
/// ([`EuclideanKernel::load_stats`]); every **query phase** call
/// ([`EuclideanKernel::query`]) broadcasts a fresh center set against the
/// already-resident rows and charges only query cycles/energy — stored
/// attribute fields are never rewritten, so queries repeat bit-identically.
pub struct EuclideanKernel {
    /// The row layout in use.
    pub layout: EuclideanLayout,
    /// Number of loaded samples.
    pub n: usize,
    ds: Dataset,
    load_stats: ExecStats,
}

impl EuclideanKernel {
    /// Allocate + load samples (row-major n×dims). One charged row write
    /// per stored attribute: `n × dims` writes of 33 bits each.
    pub fn load(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        x: &[f32],
        n: usize,
        dims: usize,
    ) -> Self {
        assert_eq!(x.len(), n * dims);
        let layout = EuclideanLayout::new(dims);
        assert!(
            (layout.width as usize) <= array.width(),
            "row width {} exceeds array width {} — reduce dims or widen rows",
            layout.width,
            array.width()
        );
        let ds = sm.alloc(n, layout.row_layout()).expect("storage full");
        let (c0, l0) = (array.cycles, array.ledger());
        for i in 0..n {
            for j in 0..dims {
                let f = layout.x[j];
                array.load_row_bits_charged(
                    ds.rows.start + i,
                    f.sign as usize,
                    33,
                    unpacked_bits(x[i * dims + j]),
                );
            }
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        EuclideanKernel {
            layout,
            n,
            ds,
            load_stats,
        }
    }

    /// Device-model cost of the load phase (paid once per dataset).
    pub fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    /// Analytic cycle cost of one query over `n_centers` centers — the
    /// query floor a resident dataset pays per repetition. The emitted
    /// microcode's shape depends only on the layout (never on center
    /// values), so the floor is exact: the wear/ledger regression suite
    /// asserts measured query cycles equal it.
    pub fn query_floor_cycles(&self, n_centers: usize) -> u64 {
        let zeros = vec![0.0f32; self.layout.dims];
        self.center_program(&zeros).cycle_estimate() * n_centers as u64
    }

    /// The per-center associative program (Fig. 7 lines 2–7).
    pub fn center_program(&self, center: &[f32]) -> Program {
        let l = &self.layout;
        assert_eq!(center.len(), l.dims);
        let mut prog = Program::new();
        // line 3: broadcast center coords — here one write per attribute
        // iteration (the center value is folded into the write key).
        // acc := 0
        prog.push(crate::isa::Instr::SetTagsAll);
        let mut zero = l.acc.exp.pattern(0);
        zero.extend(l.acc.man.pattern(0));
        zero.push((l.acc.sign, false));
        prog.push(crate::isa::Instr::Write(zero));
        for j in 0..l.dims {
            // broadcast c_j into the center field of every row
            prog.push(crate::isa::Instr::SetTagsAll);
            let bits = unpacked_bits(center[j]);
            let mut w = l.c.exp.pattern((bits >> 1) & 0xFF);
            w.extend(l.c.man.pattern(bits >> 9));
            w.push((l.c.sign, bits & 1 == 1));
            prog.push(crate::isa::Instr::Write(w));
            // diff = x_j - c   (line 5)
            micro::float::fp_sub(
                &mut prog, l.x[j], l.c, l.diff, l.ycopy, l.scratch, l.wexp,
            );
            // sq = diff^2      (line 6, associative mult)
            micro::float::fp_mul(&mut prog, l.diff, l.diff, l.sq, l.mul_scratch);
            // acc += sq        (line 7)
            micro::float::fp_add(&mut prog, l.acc, l.sq, l.diff, l.scratch, l.wexp);
            // fp_add writes into `diff` (reused as output); move back
            micro::copy_field_cond(&mut prog, l.diff.exp, l.acc.exp, &vec![]);
            micro::copy_field_cond(&mut prog, l.diff.man, l.acc.man, &vec![]);
            micro::shift::copy_col_cond(&mut prog, l.diff.sign, l.acc.sign, &vec![]);
        }
        prog
    }

    /// One-shot alias for [`EuclideanKernel::query`], kept for the
    /// load-and-run-once callers (CLI, figures, examples).
    pub fn run(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        centers: &[f32],
        n_centers: usize,
    ) -> EdResult {
        self.query(ctl, sm, centers, n_centers)
    }

    /// Query phase: run the per-center program for all centers (Fig. 7
    /// line 1 loop) against the resident samples and read distances back.
    /// Charges only query cycles/energy (the stats window opens here);
    /// repeat queries are bit-identical because stored attribute fields
    /// are read-only to the program.
    pub fn query(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        centers: &[f32],
        n_centers: usize,
    ) -> EdResult {
        let l = &self.layout;
        ctl.begin_stats();
        let mut dists = Vec::with_capacity(n_centers);
        for c in 0..n_centers {
            let prog = self.center_program(&centers[c * l.dims..(c + 1) * l.dims]);
            ctl.execute(&prog);
            // readout (storage path, not counted as kernel time by the
            // paper's convention: results stay in storage)
            let mut out = Vec::with_capacity(self.n);
            for i in 0..self.n {
                let bits = ctl.array.fetch_row_bits(
                    sm.translate(&self.ds, i),
                    l.acc.sign as usize,
                    33,
                );
                out.push(bits_to_f32(bits));
            }
            dists.push(out);
        }
        EdResult {
            dists,
            stats: ctl.stats(),
        }
    }
}

/// Per-query parameters of the ED kernel: the broadcast center set plus
/// the global top-k cut the host merge keeps per center.
#[derive(Clone, Debug)]
pub struct EdParams {
    /// `k × dims` center coordinates, row-major.
    pub centers: Vec<f32>,
    /// Number of centers.
    pub k: usize,
    /// Nearest results kept per center by the host merge.
    pub topk: usize,
}

/// Merged result of an ED query: global-row-order distances, the global
/// top-k nearest per center, and the protocol's checksum reply value.
pub struct EdOutput {
    /// `dists[center][sample]` in global row order, bit-identical to the
    /// single-device run (order-preserving concatenation merge).
    pub dists: Vec<Vec<f32>>,
    /// Per center: the global `topk` nearest `(sample_row, distance)`
    /// pairs, ascending — the host's k-way merge of per-shard top-k lists
    /// ([`merge_topk`]).
    pub nearest: Vec<Vec<(usize, f32)>>,
    /// Row-order f32 sum over all centers' distances (the protocol's
    /// checksum reply field).
    pub checksum: f32,
}

impl Kernel for EuclideanKernel {
    type Data = FloatMatrix;
    type Params = EdParams;
    type Output = Vec<Vec<f32>>;

    const NAME: &'static str = "ed";
    const VERB: &'static str = "ED";
    const QUERY_ARITY: usize = 2;

    fn data_rows(data: &FloatMatrix) -> usize {
        data.n
    }

    fn width(data: &FloatMatrix) -> usize {
        EuclideanLayout::new(data.dims).width as usize
    }

    fn load_range(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        data: &FloatMatrix,
        range: Range<usize>,
    ) -> Self {
        EuclideanKernel::load(sm, array, data.rows(&range), range.len(), data.dims)
    }

    fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    fn load_payload_bytes(&self) -> u64 {
        4 * (self.n * self.layout.dims) as u64
    }

    fn load_writes(&self) -> u64 {
        (self.n * self.layout.dims) as u64 // one write per stored attribute
    }

    fn resident_columns(&self) -> Range<u16> {
        // the D stored attributes; c/diff/sq/acc/ycopy/scratch are
        // per-query work areas
        0..(self.layout.dims as u16 * 33)
    }

    fn query_shard(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        _range: &Range<usize>,
        params: &EdParams,
    ) -> (Vec<Vec<f32>>, ExecStats) {
        let res = self.query(ctl, sm, &params.centers, params.k);
        (res.dists, res.stats)
    }

    fn query_msg_bytes(&self, range: &Range<usize>, params: &EdParams) -> (u64, u64) {
        (
            4 * (params.k * self.layout.dims) as u64,
            4 * (params.k * range.len()) as u64,
        )
    }

    fn query_floor_cycles(&self, _array: &PrinsArray, params: &EdParams) -> u64 {
        self.query_floor_cycles(params.k) // the inherent per-center floor
    }

    fn query_plan(&self, _array: &PrinsArray, params: &EdParams) -> crate::analysis::QueryPlan {
        crate::analysis::QueryPlan {
            // one per-center program per center, exactly as query dispatches
            programs: params
                .centers
                .chunks(self.layout.dims)
                .map(|c| self.center_program(c))
                .collect(),
            extra_cycles: 0, // readout is storage-path, not kernel time
        }
    }

    fn parse_params(&self, args: &[&str]) -> Result<EdParams> {
        let (k, seed): (usize, u64) = (args[0].parse()?, args[1].parse()?);
        ensure!(k > 0 && k <= 16, "k out of range");
        Ok(EdParams {
            centers: synth_uniform(k * self.layout.dims, seed),
            k,
            topk: 1,
        })
    }

    fn seeded_params(&self, q: usize, seed: u64) -> EdParams {
        EdParams {
            centers: synth_uniform(self.layout.dims, seed + 1 + q as u64),
            k: 1,
            topk: 5,
        }
    }
}

impl ShardMerge for EuclideanKernel {
    type Merged = EdOutput;

    fn merge(outputs: Vec<Vec<Vec<f32>>>, plan: &ShardPlan, params: &EdParams) -> EdOutput {
        let mut dists = Vec::with_capacity(params.k);
        let mut nearest = Vec::with_capacity(params.k);
        for c in 0..params.k {
            // borrow each shard's center-c vector; the only copy is the
            // one concatenation into the merged global vector
            let per_center: Vec<&[f32]> = outputs.iter().map(|d| d[c].as_slice()).collect();
            let local: Vec<Vec<(usize, f32)>> = per_center
                .iter()
                .zip(&plan.ranges)
                .map(|(d, rng)| local_topk(d, rng.start, params.topk))
                .collect();
            nearest.push(merge_topk(&local, params.topk));
            dists.push(merge_concat(&per_center));
        }
        let checksum = dists.iter().flat_map(|d| d.iter()).sum();
        EdOutput {
            dists,
            nearest,
            checksum,
        }
    }

    fn fields(merged: &EdOutput) -> String {
        format!("checksum={:.4}", merged.checksum)
    }

    fn bits(merged: &EdOutput) -> Vec<u64> {
        let mut bits: Vec<u64> = merged
            .dists
            .iter()
            .flat_map(|d| d.iter().map(|v| v.to_bits() as u64))
            .collect();
        for per_center in &merged.nearest {
            for &(row, dist) in per_center {
                bits.push(row as u64);
                bits.push(dist.to_bits() as u64);
            }
        }
        bits
    }
}

fn load_args(rack: &PrinsRack, args: &[&str]) -> Result<Box<dyn ResidentDyn>> {
    let [n, dims, seed] = args else {
        crate::error::bail!("usage: LOAD ED n dims seed");
    };
    let (n, dims, seed): (usize, usize, u64) = (n.parse()?, dims.parse()?, seed.parse()?);
    ensure!(
        n > 0 && n <= 1 << 16 && dims > 0 && dims <= 8,
        "size out of range"
    );
    // 4 latent clusters, like the DP synthesis (the one-shot ED verb
    // couples cluster count to its k query argument instead)
    let data = FloatMatrix::new(synth_samples(n, dims, 4, seed), n, dims);
    Ok(Box::new(Resident::<EuclideanKernel>::load(rack, &data)))
}

fn synth_load(rack: &PrinsRack, n: usize, dims: usize, seed: u64) -> Box<dyn ResidentDyn> {
    let dims = dims.clamp(1, 8);
    let data = FloatMatrix::new(synth_samples(n, dims, 4, seed), n, dims);
    Box::new(Resident::<EuclideanKernel>::load(rack, &data))
}

fn one_shot(rack: &PrinsRack, args: &[&str]) -> Result<QueryOut> {
    let [n, dims, k, seed] = args else {
        crate::error::bail!("usage: ED n dims k seed");
    };
    let (n, dims, k, seed): (usize, usize, usize, u64) =
        (n.parse()?, dims.parse()?, k.parse()?, seed.parse()?);
    ensure!(
        n > 0 && n <= 1 << 16 && dims > 0 && dims <= 8 && k > 0 && k <= 16,
        "size out of range"
    );
    let data = FloatMatrix::new(synth_samples(n, dims, k, seed), n, dims);
    let params = EdParams {
        centers: synth_uniform(k * dims, seed + 1),
        k,
        topk: 1,
    };
    Ok(one_shot_out::<EuclideanKernel>(rack, &data, &params))
}

/// The Euclidean-distance kernel's registry entry.
pub const ENTRY: KernelEntry = KernelEntry {
    name: EuclideanKernel::NAME,
    verb: EuclideanKernel::VERB,
    query_arity: EuclideanKernel::QUERY_ARITY,
    one_shot_arity: 4,
    load_usage: "LOAD ED n dims seed",
    query_usage: "ED id k seed",
    one_shot_usage: "ED n dims k seed",
    dense: true,
    write_free_queries: false,
    bits_f32: true,
    flops: |n, dims| 3.0 * (n * dims) as f64,
    load: load_args,
    synth_load,
    one_shot,
};

/// Deprecated pre-framework name for [`Resident<EuclideanKernel>`].
#[deprecated(note = "use Resident<EuclideanKernel> (algorithms::kernel)")]
pub type ResidentEuclidean = Resident<EuclideanKernel>;

/// Rack-sharded Euclidean distance, one-shot — a thin wrapper over the
/// generic framework ([`sharded`]); the merged result is on `.merged`.
/// Copies `x`/`centers` once into owned params (negligible next to the
/// simulated load); hot callers should build them and use
/// [`sharded`]/[`Resident`] directly.
pub fn euclidean_sharded(
    rack: &PrinsRack,
    x: &[f32],
    n: usize,
    dims: usize,
    centers: &[f32],
    k: usize,
    topk: usize,
) -> Sharded<EuclideanKernel> {
    let data = FloatMatrix::new(x.to_vec(), n, dims);
    let params = EdParams {
        centers: centers.to_vec(),
        k,
        topk,
    };
    sharded::<EuclideanKernel>(rack, &data, &params)
}

/// Scalar CPU baseline (the reference architecture's computation).
pub fn euclidean_baseline(x: &[f32], n: usize, dims: usize, centers: &[f32], k: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|c| {
            (0..n)
                .map(|i| {
                    (0..dims)
                        .map(|j| {
                            let d = x[i * dims + j] - centers[c * dims + j];
                            d * d
                        })
                        .sum()
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Rng;

    #[test]
    fn ed_matches_baseline_within_float_tolerance() {
        let (n, dims, k) = (48usize, 3usize, 2usize);
        let mut rng = Rng::seed_from(1);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let layout = EuclideanLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &sm, &centers, k);
        let expect = euclidean_baseline(&x, n, dims, &centers, k);
        for c in 0..k {
            for i in 0..n {
                let (got, exp) = (res.dists[c][i], expect[c][i]);
                assert!(
                    (got - exp).abs() <= 2e-5 * exp.abs().max(1.0),
                    "center {c} sample {i}: {got} vs {exp}"
                );
            }
        }
        assert!(res.stats.cycles > 0);
    }

    #[test]
    fn resident_queries_repeat_bit_identically() {
        let (n, dims, k) = (24usize, 2usize, 3usize);
        let mut rng = Rng::seed_from(11);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let rack = PrinsRack::new(2);
        let data = FloatMatrix::new(x.clone(), n, dims);
        let mut res = Resident::<EuclideanKernel>::load(&rack, &data);
        assert!(res.load_report().total_cycles > 0, "load phase is charged");
        let params = EdParams {
            centers: centers.clone(),
            k,
            topk: 2,
        };
        let one_shot = euclidean_sharded(&rack, &x, n, dims, &centers, k, 2);
        let q1 = res.query(&params);
        let q2 = res.query(&params);
        for (a, b) in [(&one_shot, &q1), (&q1, &q2)] {
            for c in 0..k {
                assert!(
                    a.merged.dists[c]
                        .iter()
                        .zip(&b.merged.dists[c])
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "center {c} distances diverge across queries"
                );
            }
            assert_eq!(a.merged.nearest, b.merged.nearest);
            assert_eq!(a.rack.total_cycles, b.rack.total_cycles);
            assert_eq!(a.rack.link_bytes, b.rack.link_bytes);
        }
    }

    #[test]
    fn query_floor_matches_measured_cycles() {
        let (n, dims, k) = (16usize, 2usize, 2usize);
        let mut rng = Rng::seed_from(21);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let layout = EuclideanLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
        // load floor: n × dims charged 33-bit row writes, 2 cycles each
        assert_eq!(kern.load_stats().cycles, 2 * (n * dims) as u64);
        assert_eq!(kern.load_stats().ledger.n_write, (n * dims) as u64);
        let mut ctl = Controller::new(array);
        let res = kern.query(&mut ctl, &sm, &centers, k);
        assert_eq!(res.stats.cycles, kern.query_floor_cycles(k));
    }

    #[test]
    fn cycles_independent_of_sample_count() {
        // The paper's central property: kernel latency does not depend on N.
        let dims = 2;
        let layout = EuclideanLayout::new(dims);
        let run_n = |n: usize| -> u64 {
            let mut rng = Rng::seed_from(7);
            let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let mut array = PrinsArray::single(n, layout.width as usize);
            let mut sm = StorageManager::new(n);
            let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
            let mut ctl = Controller::new(array);
            kern.run(&mut ctl, &sm, &[0.5, -0.5], 1).stats.cycles
        };
        assert_eq!(run_n(16), run_n(256));
    }
}
