//! Algorithm 1 (paper Fig. 7): fully associative Euclidean distance.
//!
//! Samples live one-attribute-set-per-row (a sample's D attributes occupy
//! one row's data fields). For every cluster center: broadcast the center
//! coordinates to all rows (a single tagged write per attribute — the
//! CAM broadcast), then per attribute compute dist = x − c, square it,
//! and accumulate — all in fp32 microcode, all rows in parallel. The
//! cycle count is independent of the number of samples, which is the
//! paper's headline property.

use crate::controller::{Controller, ExecStats};
use crate::host::rack::{PrinsRack, RackStats};
use crate::isa::{Field, Program, RowLayout};
use crate::micro::float::{bits_to_f32, unpacked_bits, FloatField, FpScratch, FP_SCRATCH_BITS};
use crate::micro::{self};
use crate::rcam::shard::{local_topk, merge_concat, merge_topk, ShardPlan, CMD_BYTES};
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};

/// Row layout: D attribute slots + center copy + work area.
/// 33 bits per unpacked fp32; W must fit x, c, diff, acc + scratch.
pub struct EuclideanLayout {
    /// Attributes per sample.
    pub dims: usize,
    /// The D stored attribute fields (unpacked fp32).
    pub x: Vec<FloatField>,
    /// Broadcast slot for the current center coordinate.
    pub c: FloatField,
    /// Difference work area (`x_j − c`).
    pub diff: FloatField,
    /// Squared-difference work area.
    pub sq: FloatField,
    /// Running squared-distance accumulator.
    pub acc: FloatField,
    /// Operand copy used by the fp-sub swap step.
    pub ycopy: FloatField,
    /// fp-add/sub scratch flags/fields.
    pub scratch: FpScratch,
    /// Working exponent field of the fp alignment step.
    pub wexp: Field,
    /// Base column of the fp-mul scratch area.
    pub mul_scratch: u16,
    /// Total columns the layout occupies.
    pub width: u16,
}

impl EuclideanLayout {
    /// Columns: D×33 attributes | c | diff | sq | acc | ycopy | scratch.
    pub fn new(dims: usize) -> Self {
        let mut base = 0u16;
        let mut next = |w: u16| {
            let b = base;
            base += w;
            b
        };
        let x: Vec<FloatField> = (0..dims).map(|_| FloatField::at(next(33))).collect();
        let c = FloatField::at(next(33));
        let diff = FloatField::at(next(33));
        let sq = FloatField::at(next(33));
        let acc = FloatField::at(next(33));
        let ycopy = FloatField::at(next(33));
        let scratch = FpScratch::at(next(FP_SCRATCH_BITS));
        let wexp = Field::new(next(8), 8);
        let mul_scratch = next(crate::micro::float::FP_MUL_SCRATCH_BITS);
        EuclideanLayout {
            dims,
            x,
            c,
            diff,
            sq,
            acc,
            ycopy,
            scratch,
            wexp,
            mul_scratch,
            width: base,
        }
    }

    /// The storage-manager row layout for this kernel (≥ 256-bit rows).
    pub fn row_layout(&self) -> RowLayout {
        RowLayout::new(self.width.max(256))
    }
}

/// Result of one ED run: per-sample squared distance to each center +
/// execution stats.
pub struct EdResult {
    /// dists\[center\]\[sample\]
    pub dists: Vec<Vec<f32>>,
    /// Execution statistics of the run.
    pub stats: ExecStats,
}

/// Loaded ED dataset + per-center program generator.
///
/// The **load phase** ([`EuclideanKernel::load`]) writes the samples into
/// RCAM rows once and is charged to the device model
/// ([`EuclideanKernel::load_stats`]); every **query phase** call
/// ([`EuclideanKernel::query`]) broadcasts a fresh center set against the
/// already-resident rows and charges only query cycles/energy — stored
/// attribute fields are never rewritten, so queries repeat bit-identically.
pub struct EuclideanKernel {
    /// The row layout in use.
    pub layout: EuclideanLayout,
    /// Number of loaded samples.
    pub n: usize,
    ds: Dataset,
    load_stats: ExecStats,
}

impl EuclideanKernel {
    /// Allocate + load samples (row-major n×dims). One charged row write
    /// per stored attribute: `n × dims` writes of 33 bits each.
    pub fn load(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        x: &[f32],
        n: usize,
        dims: usize,
    ) -> Self {
        assert_eq!(x.len(), n * dims);
        let layout = EuclideanLayout::new(dims);
        assert!(
            (layout.width as usize) <= array.width(),
            "row width {} exceeds array width {} — reduce dims or widen rows",
            layout.width,
            array.width()
        );
        let ds = sm.alloc(n, layout.row_layout()).expect("storage full");
        let (c0, l0) = (array.cycles, array.ledger());
        for i in 0..n {
            for j in 0..dims {
                let f = layout.x[j];
                array.load_row_bits_charged(
                    ds.rows.start + i,
                    f.sign as usize,
                    33,
                    unpacked_bits(x[i * dims + j]),
                );
            }
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        EuclideanKernel {
            layout,
            n,
            ds,
            load_stats,
        }
    }

    /// Device-model cost of the load phase (paid once per dataset).
    pub fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    /// Analytic cycle cost of one query over `n_centers` centers — the
    /// query floor a resident dataset pays per repetition. The emitted
    /// microcode's shape depends only on the layout (never on center
    /// values), so the floor is exact: the wear/ledger regression suite
    /// asserts measured query cycles equal it.
    pub fn query_floor_cycles(&self, n_centers: usize) -> u64 {
        let zeros = vec![0.0f32; self.layout.dims];
        self.center_program(&zeros).cycle_estimate() * n_centers as u64
    }

    /// The per-center associative program (Fig. 7 lines 2–7).
    pub fn center_program(&self, center: &[f32]) -> Program {
        let l = &self.layout;
        assert_eq!(center.len(), l.dims);
        let mut prog = Program::new();
        // line 3: broadcast center coords — here one write per attribute
        // iteration (the center value is folded into the write key).
        // acc := 0
        prog.push(crate::isa::Instr::SetTagsAll);
        let mut zero = l.acc.exp.pattern(0);
        zero.extend(l.acc.man.pattern(0));
        zero.push((l.acc.sign, false));
        prog.push(crate::isa::Instr::Write(zero));
        for j in 0..l.dims {
            // broadcast c_j into the center field of every row
            prog.push(crate::isa::Instr::SetTagsAll);
            let bits = unpacked_bits(center[j]);
            let mut w = l.c.exp.pattern((bits >> 1) & 0xFF);
            w.extend(l.c.man.pattern(bits >> 9));
            w.push((l.c.sign, bits & 1 == 1));
            prog.push(crate::isa::Instr::Write(w));
            // diff = x_j - c   (line 5)
            micro::float::fp_sub(
                &mut prog, l.x[j], l.c, l.diff, l.ycopy, l.scratch, l.wexp,
            );
            // sq = diff^2      (line 6, associative mult)
            micro::float::fp_mul(&mut prog, l.diff, l.diff, l.sq, l.mul_scratch);
            // acc += sq        (line 7)
            micro::float::fp_add(&mut prog, l.acc, l.sq, l.diff, l.scratch, l.wexp);
            // fp_add writes into `diff` (reused as output); move back
            micro::copy_field_cond(&mut prog, l.diff.exp, l.acc.exp, &vec![]);
            micro::copy_field_cond(&mut prog, l.diff.man, l.acc.man, &vec![]);
            micro::shift::copy_col_cond(&mut prog, l.diff.sign, l.acc.sign, &vec![]);
        }
        prog
    }

    /// One-shot alias for [`EuclideanKernel::query`], kept for the
    /// load-and-run-once callers (CLI, figures, examples).
    pub fn run(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        centers: &[f32],
        n_centers: usize,
    ) -> EdResult {
        self.query(ctl, sm, centers, n_centers)
    }

    /// Query phase: run the per-center program for all centers (Fig. 7
    /// line 1 loop) against the resident samples and read distances back.
    /// Charges only query cycles/energy (the stats window opens here);
    /// repeat queries are bit-identical because stored attribute fields
    /// are read-only to the program.
    pub fn query(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        centers: &[f32],
        n_centers: usize,
    ) -> EdResult {
        let l = &self.layout;
        ctl.begin_stats();
        let mut dists = Vec::with_capacity(n_centers);
        for c in 0..n_centers {
            let prog = self.center_program(&centers[c * l.dims..(c + 1) * l.dims]);
            ctl.execute(&prog);
            // readout (storage path, not counted as kernel time by the
            // paper's convention: results stay in storage)
            let mut out = Vec::with_capacity(self.n);
            for i in 0..self.n {
                let bits = ctl.array.fetch_row_bits(
                    sm.translate(&self.ds, i),
                    l.acc.sign as usize,
                    33,
                );
                out.push(bits_to_f32(bits));
            }
            dists.push(out);
        }
        EdResult {
            dists,
            stats: ctl.stats(),
        }
    }
}

/// Result of a rack-sharded Euclidean-distance run.
pub struct ShardedEdResult {
    /// `dists[center][sample]` in global row order, bit-identical to the
    /// single-device run (order-preserving concatenation merge).
    pub dists: Vec<Vec<f32>>,
    /// Per center: the global `topk` nearest `(sample_row, distance)`
    /// pairs, ascending — the host's k-way merge of per-shard top-k lists
    /// ([`merge_topk`]).
    pub nearest: Vec<Vec<(usize, f32)>>,
    /// Row-order f32 sum over all centers' distances (the protocol's
    /// checksum reply field).
    pub checksum: f32,
    /// Rack-level cycle/energy statistics (slowest shard + host link).
    pub rack: RackStats,
}

/// One shard's resident ED state: the controller owning the shard array,
/// the shard's storage manager, and the loaded kernel.
struct EdShard {
    ctl: Controller,
    sm: StorageManager,
    kern: EuclideanKernel,
}

/// A rack-resident ED dataset: samples row-range-partitioned over the
/// rack's shards, loaded **once**, then queried many times with fresh
/// center sets. Each query replays the Fig. 7 program on every shard
/// concurrently against the already-resident rows and merges host-side
/// exactly like the one-shot path (order-preserving concat + k-way top-k
/// merge), so query results are bit-identical to [`euclidean_sharded`]
/// while charging only query cycles plus the per-query link messages.
pub struct ResidentEuclidean {
    rack: PrinsRack,
    plan: ShardPlan,
    dims: usize,
    /// Loaded sample count (global, across all shards).
    pub n: usize,
    shards: Vec<EdShard>,
    load: RackStats,
}

impl ResidentEuclidean {
    /// Load phase: partition `x` (row-major n×dims) over the rack and
    /// write every shard's slice into its array once. The host link is
    /// charged one command + sample payload per shard; per-shard load
    /// cycles/energy come from the charged storage writes.
    pub fn load(rack: &PrinsRack, x: &[f32], n: usize, dims: usize) -> Self {
        assert_eq!(x.len(), n * dims);
        let plan = ShardPlan::rows(n, rack.n_shards());
        let width = EuclideanLayout::new(dims).width as usize;
        let shards = rack.run_shards(&plan, |_s, r| {
            let rows = r.len();
            let xs = &x[r.start * dims..r.end * dims];
            let mut array = rack.shard_array(rows, width);
            let mut sm = StorageManager::new(array.total_rows());
            let kern = EuclideanKernel::load(&mut sm, &mut array, xs, rows, dims);
            EdShard {
                ctl: Controller::new(array),
                sm,
                kern,
            }
        });
        let load_stats: Vec<ExecStats> =
            shards.iter().map(|s| s.kern.load_stats().clone()).collect();
        let payload: Vec<u64> = plan
            .ranges
            .iter()
            .map(|r| 4 * (r.len() * dims) as u64)
            .collect();
        let load = rack.finish_load(load_stats, &payload);
        ResidentEuclidean {
            rack: rack.clone(),
            plan,
            dims,
            n,
            shards,
            load,
        }
    }

    /// Device + link cost of the load phase (paid once per dataset).
    pub fn load_report(&self) -> &RackStats {
        &self.load
    }

    /// Query phase: broadcast `k` centers to every shard concurrently and
    /// merge distances / global top-`topk` nearest host-side. Chargeable
    /// work is the per-shard query program plus the per-query command and
    /// readback link messages — zero load-phase writes.
    pub fn query(&mut self, centers: &[f32], k: usize, topk: usize) -> ShardedEdResult {
        assert_eq!(centers.len(), k * self.dims);
        let plan = &self.plan;
        let runs = self.rack.query_shards(&mut self.shards, |_i, sh| {
            let res = sh.kern.query(&mut sh.ctl, &sh.sm, centers, k);
            (res.dists, res.stats)
        });
        let (shard_dists, stats): (Vec<_>, Vec<_>) = runs.into_iter().unzip();
        let mut dists = Vec::with_capacity(k);
        let mut nearest = Vec::with_capacity(k);
        for c in 0..k {
            // borrow each shard's center-c vector; the only copy is the
            // one concatenation into the merged global vector
            let per_center: Vec<&[f32]> = shard_dists
                .iter()
                .map(|d: &Vec<Vec<f32>>| d[c].as_slice())
                .collect();
            let local: Vec<Vec<(usize, f32)>> = per_center
                .iter()
                .zip(&plan.ranges)
                .map(|(d, rng)| local_topk(d, rng.start, topk))
                .collect();
            nearest.push(merge_topk(&local, topk));
            dists.push(merge_concat(&per_center));
        }
        let checksum = dists.iter().flat_map(|d| d.iter()).sum();
        let mut msgs = Vec::with_capacity(2 * plan.shards());
        for rng in &plan.ranges {
            msgs.push(CMD_BYTES + 4 * (k * self.dims) as u64); // command + centers
            msgs.push(4 * (k * rng.len()) as u64); // per-shard distance readback
        }
        ShardedEdResult {
            dists,
            nearest,
            checksum,
            rack: self.rack.finish(stats, &msgs),
        }
    }
}

/// Rack-sharded Euclidean distance, one-shot: load the samples onto the
/// rack and run a single query — exactly
/// [`ResidentEuclidean::load`] followed by one
/// [`ResidentEuclidean::query`], whose per-shard stats windows and merge
/// path it shares. The reported [`RackStats`] cover the query phase only
/// (the load phase's cost is on [`ResidentEuclidean::load_report`]).
pub fn euclidean_sharded(
    rack: &PrinsRack,
    x: &[f32],
    n: usize,
    dims: usize,
    centers: &[f32],
    k: usize,
    topk: usize,
) -> ShardedEdResult {
    ResidentEuclidean::load(rack, x, n, dims).query(centers, k, topk)
}

/// Scalar CPU baseline (the reference architecture's computation).
pub fn euclidean_baseline(x: &[f32], n: usize, dims: usize, centers: &[f32], k: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|c| {
            (0..n)
                .map(|i| {
                    (0..dims)
                        .map(|j| {
                            let d = x[i * dims + j] - centers[c * dims + j];
                            d * d
                        })
                        .sum()
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Rng;

    #[test]
    fn ed_matches_baseline_within_float_tolerance() {
        let (n, dims, k) = (48usize, 3usize, 2usize);
        let mut rng = Rng::seed_from(1);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let layout = EuclideanLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &sm, &centers, k);
        let expect = euclidean_baseline(&x, n, dims, &centers, k);
        for c in 0..k {
            for i in 0..n {
                let (got, exp) = (res.dists[c][i], expect[c][i]);
                assert!(
                    (got - exp).abs() <= 2e-5 * exp.abs().max(1.0),
                    "center {c} sample {i}: {got} vs {exp}"
                );
            }
        }
        assert!(res.stats.cycles > 0);
    }

    #[test]
    fn resident_queries_repeat_bit_identically() {
        let (n, dims, k) = (24usize, 2usize, 3usize);
        let mut rng = Rng::seed_from(11);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let rack = PrinsRack::new(2);
        let mut res = ResidentEuclidean::load(&rack, &x, n, dims);
        assert!(res.load_report().total_cycles > 0, "load phase is charged");
        let one_shot = euclidean_sharded(&rack, &x, n, dims, &centers, k, 2);
        let q1 = res.query(&centers, k, 2);
        let q2 = res.query(&centers, k, 2);
        for (a, b) in [(&one_shot, &q1), (&q1, &q2)] {
            for c in 0..k {
                assert!(
                    a.dists[c]
                        .iter()
                        .zip(&b.dists[c])
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "center {c} distances diverge across queries"
                );
            }
            assert_eq!(a.nearest, b.nearest);
            assert_eq!(a.rack.total_cycles, b.rack.total_cycles);
            assert_eq!(a.rack.link_bytes, b.rack.link_bytes);
        }
    }

    #[test]
    fn query_floor_matches_measured_cycles() {
        let (n, dims, k) = (16usize, 2usize, 2usize);
        let mut rng = Rng::seed_from(21);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let layout = EuclideanLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
        // load floor: n × dims charged 33-bit row writes, 2 cycles each
        assert_eq!(kern.load_stats().cycles, 2 * (n * dims) as u64);
        assert_eq!(kern.load_stats().ledger.n_write, (n * dims) as u64);
        let mut ctl = Controller::new(array);
        let res = kern.query(&mut ctl, &sm, &centers, k);
        assert_eq!(res.stats.cycles, kern.query_floor_cycles(k));
    }

    #[test]
    fn cycles_independent_of_sample_count() {
        // The paper's central property: kernel latency does not depend on N.
        let dims = 2;
        let layout = EuclideanLayout::new(dims);
        let run_n = |n: usize| -> u64 {
            let mut rng = Rng::seed_from(7);
            let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let mut array = PrinsArray::single(n, layout.width as usize);
            let mut sm = StorageManager::new(n);
            let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
            let mut ctl = Controller::new(array);
            kern.run(&mut ctl, &sm, &[0.5, -0.5], 1).stats.cycles
        };
        assert_eq!(run_n(16), run_n(256));
    }
}
