//! Algorithm 1 (paper Fig. 7): fully associative Euclidean distance.
//!
//! Samples live one-attribute-set-per-row (a sample's D attributes occupy
//! one row's data fields). For every cluster center: broadcast the center
//! coordinates to all rows (a single tagged write per attribute — the
//! CAM broadcast), then per attribute compute dist = x − c, square it,
//! and accumulate — all in fp32 microcode, all rows in parallel. The
//! cycle count is independent of the number of samples, which is the
//! paper's headline property.
//!
//! **Center batching** (DESIGN.md §Batching & program cache): the row
//! layout carries [`MAX_ED_LANES`] parallel work lanes (own
//! c/diff/sq/acc slots each), so one sweep packs up to that many centers
//! into spare pattern columns — the accumulator zeroing and every
//! per-dimension center broadcast become **one** merged tagged write
//! shared by all lanes instead of one per center. Per-center cycles at
//! batch B drop strictly below the single-center floor (the saving is
//! `3·(dims+1)·(B−1)` cycles per full chunk); the per-lane fp pipeline
//! is unchanged, so distances stay bit-identical to the sequential
//! per-center sweep at any batch size.

use crate::algorithms::kernel::{
    one_shot_out, sharded, FloatMatrix, Kernel, KernelEntry, QueryOut, Resident, ResidentDyn,
    ShardMerge, Sharded,
};
use crate::controller::read::ReadCursor;
use crate::controller::{Controller, ExecStats};
use crate::error::{ensure, Result};
use crate::host::rack::PrinsRack;
use crate::isa::{Field, Program, RowLayout};
use crate::micro::float::{bits_to_f32, unpacked_bits, FloatField, FpScratch, FP_SCRATCH_BITS};
use crate::micro::{self};
use crate::rcam::shard::{local_topk, merge_concat, merge_topk, ShardPlan};
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};
use crate::workloads::{synth_samples, synth_uniform};
use std::ops::Range;

/// Most centers one ED sweep packs into the layout's parallel work
/// lanes — the in-array batch bound (wire `k`, CLI `--batch`).
pub const MAX_ED_LANES: usize = 4;

/// One center-batching work lane: private c/diff/sq/acc slots, so the
/// lane's fp pipeline never aliases another lane's operands. Lane 0
/// occupies the classic single-center columns.
#[derive(Clone, Copy, Debug)]
pub struct EdLane {
    /// Broadcast slot for this lane's center coordinate.
    pub c: FloatField,
    /// Difference work area (`x_j − c`).
    pub diff: FloatField,
    /// Squared-difference work area.
    pub sq: FloatField,
    /// Running squared-distance accumulator.
    pub acc: FloatField,
}

/// Row layout: D attribute slots + center copy + work area.
/// 33 bits per unpacked fp32; W must fit x, c, diff, acc + scratch.
pub struct EuclideanLayout {
    /// Attributes per sample.
    pub dims: usize,
    /// The D stored attribute fields (unpacked fp32).
    pub x: Vec<FloatField>,
    /// Broadcast slot for the current center coordinate (lane 0).
    pub c: FloatField,
    /// Difference work area (`x_j − c`, lane 0).
    pub diff: FloatField,
    /// Squared-difference work area (lane 0).
    pub sq: FloatField,
    /// Running squared-distance accumulator (lane 0).
    pub acc: FloatField,
    /// Operand copy used by the fp-sub swap step (shared: lane fp ops
    /// run sequentially inside one sweep).
    pub ycopy: FloatField,
    /// fp-add/sub scratch flags/fields (shared across lanes).
    pub scratch: FpScratch,
    /// Working exponent field of the fp alignment step (shared).
    pub wexp: Field,
    /// Base column of the fp-mul scratch area (shared).
    pub mul_scratch: u16,
    /// The [`MAX_ED_LANES`] work lanes. `lanes[0]` aliases the legacy
    /// `c`/`diff`/`sq`/`acc` columns, so a 1-lane sweep is bit- and
    /// cycle-identical to the pre-batching per-center program.
    pub lanes: Vec<EdLane>,
    /// Total columns the layout occupies.
    pub width: u16,
}

impl EuclideanLayout {
    /// Columns: D×33 attributes | c | diff | sq | acc | ycopy | scratch
    /// | lanes 1…MAX−1 (4×33 each).
    pub fn new(dims: usize) -> Self {
        let mut base = 0u16;
        let mut next = |w: u16| {
            let b = base;
            base += w;
            b
        };
        let x: Vec<FloatField> = (0..dims).map(|_| FloatField::at(next(33))).collect();
        let c = FloatField::at(next(33));
        let diff = FloatField::at(next(33));
        let sq = FloatField::at(next(33));
        let acc = FloatField::at(next(33));
        let ycopy = FloatField::at(next(33));
        let scratch = FpScratch::at(next(FP_SCRATCH_BITS));
        let wexp = Field::new(next(8), 8);
        let mul_scratch = next(crate::micro::float::FP_MUL_SCRATCH_BITS);
        let mut lanes = vec![EdLane { c, diff, sq, acc }];
        for _ in 1..MAX_ED_LANES {
            lanes.push(EdLane {
                c: FloatField::at(next(33)),
                diff: FloatField::at(next(33)),
                sq: FloatField::at(next(33)),
                acc: FloatField::at(next(33)),
            });
        }
        EuclideanLayout {
            dims,
            x,
            c,
            diff,
            sq,
            acc,
            ycopy,
            scratch,
            wexp,
            mul_scratch,
            lanes,
            width: base,
        }
    }

    /// The storage-manager row layout for this kernel (≥ 256-bit rows).
    pub fn row_layout(&self) -> RowLayout {
        RowLayout::new(self.width.max(256))
    }
}

/// Result of one ED run: per-sample squared distance to each center +
/// execution stats.
pub struct EdResult {
    /// dists\[center\]\[sample\]
    pub dists: Vec<Vec<f32>>,
    /// Execution statistics of the run.
    pub stats: ExecStats,
}

/// Loaded ED dataset + per-center program generator.
///
/// The **load phase** ([`EuclideanKernel::load`]) writes the samples into
/// RCAM rows once and is charged to the device model
/// ([`EuclideanKernel::load_stats`]); every **query phase** call
/// ([`EuclideanKernel::query`]) broadcasts a fresh center set against the
/// already-resident rows and charges only query cycles/energy — stored
/// attribute fields are never rewritten, so queries repeat bit-identically.
pub struct EuclideanKernel {
    /// The row layout in use.
    pub layout: EuclideanLayout,
    /// Number of loaded samples.
    pub n: usize,
    ds: Dataset,
    load_stats: ExecStats,
}

impl EuclideanKernel {
    /// Allocate + load samples (row-major n×dims). One charged row write
    /// per stored attribute: `n × dims` writes of 33 bits each.
    pub fn load(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        x: &[f32],
        n: usize,
        dims: usize,
    ) -> Self {
        assert_eq!(x.len(), n * dims);
        let layout = EuclideanLayout::new(dims);
        assert!(
            (layout.width as usize) <= array.width(),
            "row width {} exceeds array width {} — reduce dims or widen rows",
            layout.width,
            array.width()
        );
        let ds = sm.alloc(n, layout.row_layout()).expect("storage full");
        let (c0, l0) = (array.cycles, array.ledger());
        for i in 0..n {
            for j in 0..dims {
                let f = layout.x[j];
                array.load_row_bits_charged(
                    ds.rows.start + i,
                    f.sign as usize,
                    33,
                    unpacked_bits(x[i * dims + j]),
                );
            }
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        EuclideanKernel {
            layout,
            n,
            ds,
            load_stats,
        }
    }

    /// Device-model cost of the load phase (paid once per dataset).
    pub fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    /// Analytic cycle cost of one query over `n_centers` centers — the
    /// query floor a resident dataset pays per repetition, with the
    /// centers chunked into [`MAX_ED_LANES`]-lane sweeps exactly as
    /// [`EuclideanKernel::query`] dispatches them. The emitted
    /// microcode's shape depends only on the layout and the lane count
    /// (never on center values), so the floor is exact: the wear/ledger
    /// regression suite asserts measured query cycles equal it.
    pub fn query_floor_cycles(&self, n_centers: usize) -> u64 {
        let zeros = vec![0.0f32; n_centers * self.layout.dims];
        self.sweep_programs(&zeros, n_centers)
            .iter()
            .map(|p| p.cycle_estimate())
            .sum()
    }

    /// The per-center associative program (Fig. 7 lines 2–7) — a 1-lane
    /// [`EuclideanKernel::sweep_program`].
    pub fn center_program(&self, center: &[f32]) -> Program {
        assert_eq!(center.len(), self.layout.dims);
        self.sweep_program(center)
    }

    /// One batched sweep over ≤ [`MAX_ED_LANES`] centers (`chunk` is
    /// their row-major coordinates): the accumulator zeroing and every
    /// per-dimension broadcast are **one** merged tagged write covering
    /// all lanes' slots; the per-lane fp pipeline then runs sequentially
    /// over disjoint lane fields (shared ycopy/scratch areas are dead
    /// between lanes), so lane values are bit-identical to the
    /// sequential per-center program.
    pub fn sweep_program(&self, chunk: &[f32]) -> Program {
        let l = &self.layout;
        assert!(
            !chunk.is_empty() && chunk.len() % l.dims == 0,
            "sweep chunk must hold whole centers"
        );
        let lanes = chunk.len() / l.dims;
        assert!(lanes <= MAX_ED_LANES, "sweep chunk exceeds the lane count");
        let mut prog = Program::new();
        // acc := 0, all lanes in one write
        prog.push(crate::isa::Instr::SetTagsAll);
        let mut zero = Vec::new();
        for slot in &l.lanes[..lanes] {
            zero.extend(slot.acc.exp.pattern(0));
            zero.extend(slot.acc.man.pattern(0));
            zero.push((slot.acc.sign, false));
        }
        prog.push(crate::isa::Instr::Write(zero));
        for j in 0..l.dims {
            // line 3: broadcast every lane's c_j in one tagged write
            // (the center values are folded into the write key)
            prog.push(crate::isa::Instr::SetTagsAll);
            let mut w = Vec::new();
            for (lane, slot) in l.lanes[..lanes].iter().enumerate() {
                let bits = unpacked_bits(chunk[lane * l.dims + j]);
                w.extend(slot.c.exp.pattern((bits >> 1) & 0xFF));
                w.extend(slot.c.man.pattern(bits >> 9));
                w.push((slot.c.sign, bits & 1 == 1));
            }
            prog.push(crate::isa::Instr::Write(w));
            for slot in &l.lanes[..lanes] {
                // diff = x_j - c   (line 5)
                micro::float::fp_sub(
                    &mut prog, l.x[j], slot.c, slot.diff, l.ycopy, l.scratch, l.wexp,
                );
                // sq = diff^2      (line 6, associative mult)
                micro::float::fp_mul(&mut prog, slot.diff, slot.diff, slot.sq, l.mul_scratch);
                // acc += sq        (line 7)
                micro::float::fp_add(&mut prog, slot.acc, slot.sq, slot.diff, l.scratch, l.wexp);
                // fp_add writes into `diff` (reused as output); move back
                micro::copy_field_cond(&mut prog, slot.diff.exp, slot.acc.exp, &vec![]);
                micro::copy_field_cond(&mut prog, slot.diff.man, slot.acc.man, &vec![]);
                micro::shift::copy_col_cond(&mut prog, slot.diff.sign, slot.acc.sign, &vec![]);
            }
        }
        prog
    }

    /// The query's sweep programs, in dispatch order: the centers
    /// chunked into [`MAX_ED_LANES`]-lane sweeps.
    pub fn sweep_programs(&self, centers: &[f32], n_centers: usize) -> Vec<Program> {
        assert_eq!(centers.len(), n_centers * self.layout.dims);
        centers
            .chunks(MAX_ED_LANES * self.layout.dims)
            .map(|chunk| self.sweep_program(chunk))
            .collect()
    }

    /// One-shot alias for [`EuclideanKernel::query`], kept for the
    /// load-and-run-once callers (CLI, figures, examples).
    pub fn run(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        centers: &[f32],
        n_centers: usize,
    ) -> EdResult {
        self.query(ctl, sm, centers, n_centers)
    }

    /// Query phase: run the per-center program for all centers (Fig. 7
    /// line 1 loop) against the resident samples and read distances back.
    /// Charges only query cycles/energy (the stats window opens here);
    /// repeat queries are bit-identical because stored attribute fields
    /// are read-only to the program.
    pub fn query(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        centers: &[f32],
        n_centers: usize,
    ) -> EdResult {
        let programs = self.sweep_programs(&centers[..n_centers * self.layout.dims], n_centers);
        self.query_with(ctl, sm, &programs, n_centers)
    }

    /// Execute an already-synthesized sweep sequence and read each
    /// lane's distances back. Shared by the fresh and cached query
    /// paths, so the two are bit-identical by construction.
    fn query_with(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        programs: &[Program],
        n_centers: usize,
    ) -> EdResult {
        ctl.begin_stats();
        let mut dists = Vec::with_capacity(n_centers);
        let mut remaining = n_centers;
        for prog in programs {
            ctl.execute(prog);
            // readout (storage path, not counted as kernel time by the
            // paper's convention: results stay in storage)
            for slot in &self.layout.lanes[..remaining.min(MAX_ED_LANES)] {
                dists.push(self.fetch_lane(ctl, sm, slot));
            }
            remaining = remaining.saturating_sub(MAX_ED_LANES);
        }
        EdResult {
            dists,
            stats: ctl.stats(),
        }
    }

    /// Read one lane's per-sample squared distances out of its
    /// accumulator slot (storage path, uncharged).
    fn fetch_lane(&self, ctl: &Controller, sm: &StorageManager, slot: &EdLane) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let bits =
                ctl.array
                    .fetch_row_bits(sm.translate(&self.ds, i), slot.acc.sign as usize, 33);
            out.push(bits_to_f32(bits));
        }
        out
    }
}

/// Per-query parameters of the ED kernel: the broadcast center set plus
/// the global top-k cut the host merge keeps per center.
#[derive(Clone, Debug)]
pub struct EdParams {
    /// `k × dims` center coordinates, row-major.
    pub centers: Vec<f32>,
    /// Number of centers.
    pub k: usize,
    /// Nearest results kept per center by the host merge.
    pub topk: usize,
}

/// Merged result of an ED query: global-row-order distances, the global
/// top-k nearest per center, and the protocol's checksum reply value.
pub struct EdOutput {
    /// `dists[center][sample]` in global row order, bit-identical to the
    /// single-device run (order-preserving concatenation merge).
    pub dists: Vec<Vec<f32>>,
    /// Per center: the global `topk` nearest `(sample_row, distance)`
    /// pairs, ascending — the host's k-way merge of per-shard top-k lists
    /// ([`merge_topk`]).
    pub nearest: Vec<Vec<(usize, f32)>>,
    /// Row-order f32 sum over all centers' distances (the protocol's
    /// checksum reply field).
    pub checksum: f32,
}

impl Kernel for EuclideanKernel {
    type Data = FloatMatrix;
    type Params = EdParams;
    type Output = Vec<Vec<f32>>;

    const NAME: &'static str = "ed";
    const VERB: &'static str = "ED";
    const QUERY_ARITY: usize = 2;
    // the sweep programs write scratch columns only (verified statically
    // by the `prins verify` overlay C03 contract), so queries run
    // concurrently through the scratch-overlay cursor
    const SHARED_READ: bool = true;

    fn data_rows(data: &FloatMatrix) -> usize {
        data.n
    }

    fn width(data: &FloatMatrix) -> usize {
        EuclideanLayout::new(data.dims).width as usize
    }

    fn load_range(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        data: &FloatMatrix,
        range: Range<usize>,
    ) -> Self {
        EuclideanKernel::load(sm, array, data.rows(&range), range.len(), data.dims)
    }

    fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    fn load_payload_bytes(&self) -> u64 {
        4 * (self.n * self.layout.dims) as u64
    }

    fn load_writes(&self) -> u64 {
        (self.n * self.layout.dims) as u64 // one write per stored attribute
    }

    fn resident_columns(&self) -> Range<u16> {
        // the D stored attributes; c/diff/sq/acc/ycopy/scratch are
        // per-query work areas
        0..(self.layout.dims as u16 * 33)
    }

    fn query_shard(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        _range: &Range<usize>,
        params: &EdParams,
    ) -> (Vec<Vec<f32>>, ExecStats) {
        let res = self.query(ctl, sm, &params.centers, params.k);
        (res.dists, res.stats)
    }

    fn query_msg_bytes(&self, range: &Range<usize>, params: &EdParams) -> (u64, u64) {
        (
            4 * (params.k * self.layout.dims) as u64,
            4 * (params.k * range.len()) as u64,
        )
    }

    fn query_floor_cycles(&self, _array: &PrinsArray, params: &EdParams) -> u64 {
        self.query_floor_cycles(params.k) // the inherent chunked floor
    }

    fn query_floor_unbatched_cycles(&self, _array: &PrinsArray, params: &EdParams) -> u64 {
        // k independent single-center queries: every center pays its own
        // accumulator zeroing and per-dimension broadcast writes
        params.k as u64 * self.query_floor_cycles(1)
    }

    fn query_plan(&self, _array: &PrinsArray, params: &EdParams) -> crate::analysis::QueryPlan {
        crate::analysis::QueryPlan {
            // one sweep program per ≤MAX_ED_LANES-center chunk, exactly
            // as query dispatches
            programs: self.sweep_programs(&params.centers, params.k),
            extra_cycles: 0, // readout is storage-path, not kernel time
        }
    }

    fn params_key(&self, params: &EdParams) -> Option<String> {
        // the plan folds the center bits into its write keys, so the
        // cache key must carry the exact values (topk is host-side merge
        // only and correctly excluded)
        let mut key = params.k.to_string();
        for c in &params.centers {
            key.push(':');
            key.push_str(&format!("{:08x}", c.to_bits()));
        }
        Some(key)
    }

    fn query_shard_planned(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        _range: &Range<usize>,
        params: &EdParams,
        plan: &crate::analysis::QueryPlan,
    ) -> Option<(Vec<Vec<f32>>, ExecStats)> {
        let res = self.query_with(ctl, sm, &plan.programs, params.k);
        Some((res.dists, res.stats))
    }

    fn query_shard_overlay(
        &self,
        cur: &mut ReadCursor<'_>,
        sm: &StorageManager,
        _range: &Range<usize>,
        params: &EdParams,
        plan: &crate::analysis::QueryPlan,
    ) -> Option<(Vec<Vec<f32>>, ExecStats)> {
        // mirror of query_with on the overlay cursor: execute each sweep,
        // then read every active lane's accumulator back overlay-first
        let mut dists = Vec::with_capacity(params.k);
        let mut remaining = params.k;
        for prog in &plan.programs {
            cur.execute_overlay(prog).ok()?;
            for slot in &self.layout.lanes[..remaining.min(MAX_ED_LANES)] {
                let mut out = Vec::with_capacity(self.n);
                for i in 0..self.n {
                    let bits =
                        cur.fetch_row_bits(sm.translate(&self.ds, i), slot.acc.sign as usize, 33);
                    out.push(bits_to_f32(bits));
                }
                dists.push(out);
            }
            remaining = remaining.saturating_sub(MAX_ED_LANES);
        }
        cur.add_cycles(plan.extra_cycles);
        Some((dists, cur.stats_microcoded()))
    }

    fn parse_params(&self, args: &[&str]) -> Result<EdParams> {
        let (k, seed): (usize, u64) = (args[0].parse()?, args[1].parse()?);
        ensure!(k > 0 && k <= 16, "k out of range");
        Ok(EdParams {
            centers: synth_uniform(k * self.layout.dims, seed),
            k,
            topk: 1,
        })
    }

    fn seeded_params(&self, q: usize, seed: u64) -> EdParams {
        // every fourth query runs a 3-center batch, so the seeded stream
        // (and the `prins verify` shape grid) covers multi-lane sweeps
        let k = if q % 4 == 3 { 3 } else { 1 };
        EdParams {
            centers: synth_uniform(k * self.layout.dims, seed + 1 + q as u64),
            k,
            topk: 5,
        }
    }

    fn seeded_batch(&self, q: usize, seed: u64, batch: usize) -> Option<EdParams> {
        if batch == 0 || batch > 16 {
            return None;
        }
        Some(EdParams {
            centers: synth_uniform(batch * self.layout.dims, seed + 1 + q as u64),
            k: batch,
            topk: 1,
        })
    }
}

impl ShardMerge for EuclideanKernel {
    type Merged = EdOutput;

    fn merge(outputs: Vec<Vec<Vec<f32>>>, plan: &ShardPlan, params: &EdParams) -> EdOutput {
        let mut dists = Vec::with_capacity(params.k);
        let mut nearest = Vec::with_capacity(params.k);
        for c in 0..params.k {
            // borrow each shard's center-c vector; the only copy is the
            // one concatenation into the merged global vector
            let per_center: Vec<&[f32]> = outputs.iter().map(|d| d[c].as_slice()).collect();
            let local: Vec<Vec<(usize, f32)>> = per_center
                .iter()
                .zip(&plan.ranges)
                .map(|(d, rng)| local_topk(d, rng.start, params.topk))
                .collect();
            nearest.push(merge_topk(&local, params.topk));
            dists.push(merge_concat(&per_center));
        }
        let checksum = dists.iter().flat_map(|d| d.iter()).sum();
        EdOutput {
            dists,
            nearest,
            checksum,
        }
    }

    fn fields(merged: &EdOutput) -> String {
        format!("checksum={:.4}", merged.checksum)
    }

    fn bits(merged: &EdOutput) -> Vec<u64> {
        let mut bits: Vec<u64> = merged
            .dists
            .iter()
            .flat_map(|d| d.iter().map(|v| v.to_bits() as u64))
            .collect();
        for per_center in &merged.nearest {
            for &(row, dist) in per_center {
                bits.push(row as u64);
                bits.push(dist.to_bits() as u64);
            }
        }
        bits
    }
}

fn load_args(rack: &PrinsRack, args: &[&str]) -> Result<Box<dyn ResidentDyn>> {
    let [n, dims, seed] = args else {
        crate::error::bail!("usage: LOAD ED n dims seed");
    };
    let (n, dims, seed): (usize, usize, u64) = (n.parse()?, dims.parse()?, seed.parse()?);
    ensure!(
        n > 0 && n <= 1 << 16 && dims > 0 && dims <= 8,
        "size out of range"
    );
    // 4 latent clusters, like the DP synthesis (the one-shot ED verb
    // couples cluster count to its k query argument instead)
    let data = FloatMatrix::new(synth_samples(n, dims, 4, seed), n, dims);
    Ok(Box::new(Resident::<EuclideanKernel>::load(rack, &data)))
}

fn synth_load(rack: &PrinsRack, n: usize, dims: usize, seed: u64) -> Box<dyn ResidentDyn> {
    let dims = dims.clamp(1, 8);
    let data = FloatMatrix::new(synth_samples(n, dims, 4, seed), n, dims);
    Box::new(Resident::<EuclideanKernel>::load(rack, &data))
}

fn one_shot(rack: &PrinsRack, args: &[&str]) -> Result<QueryOut> {
    let [n, dims, k, seed] = args else {
        crate::error::bail!("usage: ED n dims k seed");
    };
    let (n, dims, k, seed): (usize, usize, usize, u64) =
        (n.parse()?, dims.parse()?, k.parse()?, seed.parse()?);
    ensure!(
        n > 0 && n <= 1 << 16 && dims > 0 && dims <= 8 && k > 0 && k <= 16,
        "size out of range"
    );
    let data = FloatMatrix::new(synth_samples(n, dims, k, seed), n, dims);
    let params = EdParams {
        centers: synth_uniform(k * dims, seed + 1),
        k,
        topk: 1,
    };
    Ok(one_shot_out::<EuclideanKernel>(rack, &data, &params))
}

/// The Euclidean-distance kernel's registry entry.
pub const ENTRY: KernelEntry = KernelEntry {
    name: EuclideanKernel::NAME,
    verb: EuclideanKernel::VERB,
    query_arity: EuclideanKernel::QUERY_ARITY,
    one_shot_arity: 4,
    load_usage: "LOAD ED n dims seed",
    query_usage: "ED id k seed",
    one_shot_usage: "ED n dims k seed",
    dense: true,
    write_free_queries: false,
    overlay_queries: true,
    coalesce_queries: false,
    bits_f32: true,
    flops: |n, dims| 3.0 * (n * dims) as f64,
    load: load_args,
    synth_load,
    one_shot,
};

/// Deprecated pre-framework name for [`Resident<EuclideanKernel>`].
#[deprecated(note = "use Resident<EuclideanKernel> (algorithms::kernel)")]
pub type ResidentEuclidean = Resident<EuclideanKernel>;

/// Rack-sharded Euclidean distance, one-shot — a thin wrapper over the
/// generic framework ([`sharded`]); the merged result is on `.merged`.
/// Copies `x`/`centers` once into owned params (negligible next to the
/// simulated load); hot callers should build them and use
/// [`sharded`]/[`Resident`] directly.
pub fn euclidean_sharded(
    rack: &PrinsRack,
    x: &[f32],
    n: usize,
    dims: usize,
    centers: &[f32],
    k: usize,
    topk: usize,
) -> Sharded<EuclideanKernel> {
    let data = FloatMatrix::new(x.to_vec(), n, dims);
    let params = EdParams {
        centers: centers.to_vec(),
        k,
        topk,
    };
    sharded::<EuclideanKernel>(rack, &data, &params)
}

/// Scalar CPU baseline (the reference architecture's computation).
pub fn euclidean_baseline(x: &[f32], n: usize, dims: usize, centers: &[f32], k: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|c| {
            (0..n)
                .map(|i| {
                    (0..dims)
                        .map(|j| {
                            let d = x[i * dims + j] - centers[c * dims + j];
                            d * d
                        })
                        .sum()
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Rng;

    #[test]
    fn ed_matches_baseline_within_float_tolerance() {
        let (n, dims, k) = (48usize, 3usize, 2usize);
        let mut rng = Rng::seed_from(1);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let layout = EuclideanLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &sm, &centers, k);
        let expect = euclidean_baseline(&x, n, dims, &centers, k);
        for c in 0..k {
            for i in 0..n {
                let (got, exp) = (res.dists[c][i], expect[c][i]);
                assert!(
                    (got - exp).abs() <= 2e-5 * exp.abs().max(1.0),
                    "center {c} sample {i}: {got} vs {exp}"
                );
            }
        }
        assert!(res.stats.cycles > 0);
    }

    #[test]
    fn resident_queries_repeat_bit_identically() {
        let (n, dims, k) = (24usize, 2usize, 3usize);
        let mut rng = Rng::seed_from(11);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let rack = PrinsRack::new(2);
        let data = FloatMatrix::new(x.clone(), n, dims);
        let mut res = Resident::<EuclideanKernel>::load(&rack, &data);
        assert!(res.load_report().total_cycles > 0, "load phase is charged");
        let params = EdParams {
            centers: centers.clone(),
            k,
            topk: 2,
        };
        let one_shot = euclidean_sharded(&rack, &x, n, dims, &centers, k, 2);
        let q1 = res.query(&params);
        let q2 = res.query(&params);
        for (a, b) in [(&one_shot, &q1), (&q1, &q2)] {
            for c in 0..k {
                assert!(
                    a.merged.dists[c]
                        .iter()
                        .zip(&b.merged.dists[c])
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "center {c} distances diverge across queries"
                );
            }
            assert_eq!(a.merged.nearest, b.merged.nearest);
            assert_eq!(a.rack.total_cycles, b.rack.total_cycles);
            assert_eq!(a.rack.link_bytes, b.rack.link_bytes);
        }
    }

    #[test]
    fn query_floor_matches_measured_cycles() {
        let (n, dims, k) = (16usize, 2usize, 2usize);
        let mut rng = Rng::seed_from(21);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let layout = EuclideanLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
        // load floor: n × dims charged 33-bit row writes, 2 cycles each
        assert_eq!(kern.load_stats().cycles, 2 * (n * dims) as u64);
        assert_eq!(kern.load_stats().ledger.n_write, (n * dims) as u64);
        let mut ctl = Controller::new(array);
        let res = kern.query(&mut ctl, &sm, &centers, k);
        assert_eq!(res.stats.cycles, kern.query_floor_cycles(k));
    }

    #[test]
    fn batched_sweeps_match_sequential_centers_and_beat_the_unbatched_floor() {
        // k = 6 crosses the MAX_ED_LANES chunk boundary: one 4-lane
        // sweep plus one 2-lane sweep
        let (n, dims, k) = (32usize, 3usize, 6usize);
        let mut rng = Rng::seed_from(31);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-6.0, 6.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-6.0, 6.0)).collect();
        let layout = EuclideanLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
        let mut ctl = Controller::new(array);
        let batched = kern.query(&mut ctl, &sm, &centers, k);
        assert_eq!(batched.dists.len(), k);
        // lane values are bit-identical to the sequential per-center runs
        for c in 0..k {
            let single = kern.query(&mut ctl, &sm, &centers[c * dims..(c + 1) * dims], 1);
            assert!(
                batched.dists[c]
                    .iter()
                    .zip(&single.dists[0])
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "center {c}: batched lane diverged from the sequential sweep"
            );
        }
        // measured == chunked floor, strictly below k independent
        // single-center queries: the merged broadcast writes save
        // 3·(dims+1) cycles per extra lane in every chunk (3+1 here)
        assert_eq!(batched.stats.cycles, kern.query_floor_cycles(k));
        let unbatched = k as u64 * kern.query_floor_cycles(1);
        assert!(kern.query_floor_cycles(k) < unbatched);
        assert_eq!(
            unbatched - kern.query_floor_cycles(k),
            3 * (dims as u64 + 1) * 4
        );
    }

    #[test]
    fn shared_overlay_queries_match_the_exclusive_path_bitwise() {
        // k = 6 crosses the lane-chunk boundary, so the overlay path is
        // exercised across multiple sweep programs
        let (n, dims, k) = (24usize, 2usize, 6usize);
        let mut rng = Rng::seed_from(41);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let rack = PrinsRack::new(2);
        let data = FloatMatrix::new(x, n, dims);
        let mut res = Resident::<EuclideanKernel>::load(&rack, &data);
        assert!(res.shared_readable(), "ed opts into the shared-read path");
        let params = EdParams { centers, k, topk: 2 };
        let wear0 = res.shard_wear();
        let shared = res.query_shared(&params).expect("shared path refused");
        assert_eq!(res.shard_wear(), wear0, "shared query advanced wear");
        let excl = res.query(&params);
        for c in 0..k {
            assert!(
                shared.merged.dists[c]
                    .iter()
                    .zip(&excl.merged.dists[c])
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "center {c}: shared overlay diverged from the exclusive path"
            );
        }
        assert_eq!(shared.merged.nearest, excl.merged.nearest);
        assert_eq!(
            shared.merged.checksum.to_bits(),
            excl.merged.checksum.to_bits()
        );
        assert_eq!(shared.rack.total_cycles, excl.rack.total_cycles);
        assert_eq!(shared.rack.link_bytes, excl.rack.link_bytes);
        assert_eq!(shared.rack.shard_stats, excl.rack.shard_stats);
    }

    #[test]
    fn cycles_independent_of_sample_count() {
        // The paper's central property: kernel latency does not depend on N.
        let dims = 2;
        let layout = EuclideanLayout::new(dims);
        let run_n = |n: usize| -> u64 {
            let mut rng = Rng::seed_from(7);
            let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let mut array = PrinsArray::single(n, layout.width as usize);
            let mut sm = StorageManager::new(n);
            let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
            let mut ctl = Controller::new(array);
            kern.run(&mut ctl, &sm, &[0.5, -0.5], 1).stats.cycles
        };
        assert_eq!(run_n(16), run_n(256));
    }
}
