//! The paper's workload suite (§5.4): fully associative implementations of
//! Euclidean distance, dot product, histogram, SpMV and BFS, each with a
//! scalar CPU-baseline twin for cross-validation.

pub mod bfs;
pub mod dot;
pub mod euclidean;
pub mod histogram;
pub mod spmv;

pub use bfs::{measured_teps, paper_model_teps, BfsKernel, BfsResult};
pub use dot::{dot_baseline, DotKernel};
pub use euclidean::{euclidean_baseline, EuclideanKernel};
pub use histogram::{histogram_baseline, HistogramKernel};
pub use spmv::{spmv_baseline_quantized, ReduceEngine, SpmvKernel};
