//! The paper's workload suite (§5.4): fully associative implementations of
//! Euclidean distance, dot product, histogram, SpMV and BFS, each with a
//! scalar CPU-baseline twin for cross-validation.
//!
//! Histogram, dot product, ED and SpMV additionally have `*_sharded`
//! entry points that run the same kernel partitioned over a
//! [`crate::host::rack::PrinsRack`] of shard devices with host-side
//! merging; `tests/prop_sharded_equals_single.rs` asserts their results
//! bit-identical to the single-device paths.

pub mod bfs;
pub mod dot;
pub mod euclidean;
pub mod histogram;
pub mod spmv;

pub use bfs::{measured_teps, paper_model_teps, BfsKernel, BfsResult};
pub use dot::{dot_baseline, dot_sharded, DotKernel, ShardedDotResult};
pub use euclidean::{
    euclidean_baseline, euclidean_sharded, EuclideanKernel, ShardedEdResult,
};
pub use histogram::{histogram_baseline, histogram_sharded, HistogramKernel, ShardedHistResult};
pub use spmv::{
    spmv_baseline_quantized, spmv_sharded, spmv_single, ReduceEngine, ShardedSpmvResult,
    SpmvKernel,
};
