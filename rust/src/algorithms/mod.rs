//! The paper's workload suite (§5.4): fully associative implementations of
//! Euclidean distance, dot product, histogram, SpMV and BFS, each with a
//! scalar CPU-baseline twin for cross-validation.
//!
//! Every kernel is split into an explicit **load phase** (write the
//! dataset into RCAM rows once, charged to the device model) and a
//! **query phase** (compare/tag cycles against the already-resident
//! rows): `XKernel::load` → `XKernel::query(params)`. Repeated queries —
//! a new center set, a new hyperplane, new bin edges, a new x vector —
//! reuse the loaded array and charge only query cycles/energy
//! (DESIGN.md §Resident datasets).
//!
//! Every registered workload goes through the **kernel framework**
//! ([`kernel`], DESIGN.md §Kernel framework): it implements the
//! [`kernel::Kernel`] + [`kernel::ShardMerge`] traits in its own file
//! and appends one [`kernel::KernelEntry`] to the registry, which buys
//! it the generic [`kernel::Resident`] load-once / query-many rack
//! wrapper, the [`kernel::sharded`] one-shot, the server's wire verbs,
//! the CLI `run` subcommand, the bench sweeps and the registry-driven
//! bit-equality test gates (`tests/prop_sharded_equals_single.rs`,
//! `tests/resident_datasets.rs`) — with zero per-kernel code above the
//! array. The associative SEARCH kernel ([`search`]) is the reference
//! example of adding a workload in one file.
//!
//! BFS is the deliberate exception: its query writes the frontier back
//! into the resident rows, so the framework's write-free-query contract
//! does not hold and it stays a single-device, load-per-traversal
//! kernel (see [`bfs::BfsKernel`]).

pub mod bfs;
pub mod dot;
pub mod euclidean;
pub mod histogram;
pub mod kernel;
pub mod search;
pub mod spmv;

pub use bfs::{measured_teps, paper_model_teps, BfsKernel, BfsResult};
pub use dot::{dot_baseline, dot_sharded, DotKernel, DotOutput};
pub use euclidean::{euclidean_baseline, euclidean_sharded, EdOutput, EdParams, EuclideanKernel};
pub use histogram::{
    histogram_baseline, histogram_baseline_at, histogram_sharded, HistogramKernel,
};
pub use kernel::{
    find, find_name, find_verb, one_shot_out, registry, sharded, FloatMatrix, Kernel, KernelEntry,
    QueryOut, Resident, ResidentDyn, ShardMerge, ShardSlot, Sharded,
};
pub use search::{
    range_prefixes, search_baseline, SearchBatch, SearchKernel, SearchRange, MAX_SEARCH_BATCH,
};
// deprecated pre-framework aliases, re-exported so PR-4-era callers get
// the deprecation nudge instead of an unresolved-import hard break
#[allow(deprecated)]
pub use {
    dot::ResidentDot, euclidean::ResidentEuclidean, histogram::ResidentHistogram,
    spmv::ResidentSpmv,
};
pub use spmv::{
    spmv_baseline_quantized, spmv_sharded, spmv_single, ReduceEngine, SpmvKernel, SpmvOutput,
};
