//! The paper's workload suite (§5.4): fully associative implementations of
//! Euclidean distance, dot product, histogram, SpMV and BFS, each with a
//! scalar CPU-baseline twin for cross-validation.
//!
//! Every kernel is split into an explicit **load phase** (write the
//! dataset into RCAM rows once, charged to the device model) and a
//! **query phase** (compare/tag cycles against the already-resident
//! rows): `XKernel::load` → `XKernel::query(params)`. Repeated queries —
//! a new center set, a new hyperplane, new bin edges, a new x vector —
//! reuse the loaded array and charge only query cycles/energy
//! (DESIGN.md §Resident datasets).
//!
//! Histogram, dot product, ED and SpMV additionally have `*_sharded`
//! one-shot entry points and `Resident*` load-once / query-many forms
//! that keep per-shard loaded kernels alive on a
//! [`crate::host::rack::PrinsRack`] across calls with host-side merging;
//! `tests/prop_sharded_equals_single.rs` and `tests/resident_datasets.rs`
//! assert their results bit-identical to the single-device paths.

pub mod bfs;
pub mod dot;
pub mod euclidean;
pub mod histogram;
pub mod spmv;

pub use bfs::{measured_teps, paper_model_teps, BfsKernel, BfsResult};
pub use dot::{dot_baseline, dot_sharded, DotKernel, ResidentDot, ShardedDotResult};
pub use euclidean::{
    euclidean_baseline, euclidean_sharded, EuclideanKernel, ResidentEuclidean, ShardedEdResult,
};
pub use histogram::{
    histogram_baseline, histogram_baseline_at, histogram_sharded, HistogramKernel,
    ResidentHistogram, ShardedHistResult,
};
pub use spmv::{
    spmv_baseline_quantized, spmv_sharded, spmv_single, ReduceEngine, ResidentSpmv,
    ShardedSpmvResult, SpmvKernel,
};
