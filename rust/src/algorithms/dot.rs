//! Algorithm 2 (paper Fig. 8): fully associative dot product — the SVM
//! classification inner loop. For each attribute i: broadcast H_i, then
//! Mult = x_i × H_i and DP += Mult at all rows in parallel; the runtime is
//! independent of the number of vectors.

use crate::algorithms::kernel::{
    one_shot_out, sharded, FloatMatrix, Kernel, KernelEntry, QueryOut, Resident, ResidentDyn,
    ShardMerge, Sharded,
};
use crate::controller::read::ReadCursor;
use crate::controller::{Controller, ExecStats};
use crate::error::{ensure, Result};
use crate::host::rack::PrinsRack;
use crate::isa::{Field, Instr, Program, RowLayout};
use crate::micro::float::{
    bits_to_f32, unpacked_bits, FloatField, FpScratch, FP_MUL_SCRATCH_BITS, FP_SCRATCH_BITS,
};
use crate::micro::{self};
use crate::rcam::shard::{merge_concat, ShardPlan};
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};
use crate::workloads::{synth_samples, synth_uniform};
use std::ops::Range;

/// Row layout of the DP kernel: D attribute slots + broadcast/work areas.
pub struct DotLayout {
    /// Attributes per vector.
    pub dims: usize,
    /// The D stored attribute fields (unpacked fp32).
    pub x: Vec<FloatField>,
    /// Broadcast slot for the H coefficient of the current iteration.
    pub h: FloatField,
    /// Product work area (`x_j × H_j`).
    pub mult: FloatField,
    /// Running dot-product accumulator.
    pub acc: FloatField,
    /// fp-add output area (copied back into `acc`).
    pub out: FloatField,
    /// fp-add scratch flags/fields.
    pub scratch: FpScratch,
    /// Working exponent field of the fp-add alignment step.
    pub wexp: Field,
    /// Base column of the fp-mul scratch area.
    pub mul_scratch: u16,
    /// Total columns the layout occupies.
    pub width: u16,
}

impl DotLayout {
    /// Lay the fields out contiguously for `dims` attributes.
    pub fn new(dims: usize) -> Self {
        let mut base = 0u16;
        let mut next = |w: u16| {
            let b = base;
            base += w;
            b
        };
        let x: Vec<FloatField> = (0..dims).map(|_| FloatField::at(next(33))).collect();
        let h = FloatField::at(next(33));
        let mult = FloatField::at(next(33));
        let acc = FloatField::at(next(33));
        let out = FloatField::at(next(33));
        let scratch = FpScratch::at(next(FP_SCRATCH_BITS));
        let wexp = Field::new(next(8), 8);
        let mul_scratch = next(FP_MUL_SCRATCH_BITS);
        DotLayout {
            dims,
            x,
            h,
            mult,
            acc,
            out,
            scratch,
            wexp,
            mul_scratch,
            width: base,
        }
    }
}

/// Result of one dot-product run.
pub struct DotResult {
    /// Per-vector dot products, row order.
    pub dp: Vec<f32>,
    /// Execution statistics of the run.
    pub stats: ExecStats,
}

/// Loaded dot-product dataset + program generator.
///
/// Load-once / query-many: [`DotKernel::load`] writes the vectors into
/// RCAM rows once (charged, [`DotKernel::load_stats`]); each
/// [`DotKernel::query`] broadcasts a fresh H against the resident rows
/// and charges only query cycles/energy.
pub struct DotKernel {
    /// The row layout in use.
    pub layout: DotLayout,
    /// Number of loaded vectors.
    pub n: usize,
    ds: Dataset,
    load_stats: ExecStats,
}

impl DotKernel {
    /// Allocate rows and load `n` × `dims` vectors (row-major). One
    /// charged row write per stored attribute: `n × dims` × 33 bits.
    pub fn load(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        x: &[f32],
        n: usize,
        dims: usize,
    ) -> Self {
        assert_eq!(x.len(), n * dims);
        let layout = DotLayout::new(dims);
        assert!((layout.width as usize) <= array.width());
        let ds = sm
            .alloc(n, RowLayout::new(layout.width))
            .expect("storage full");
        let (c0, l0) = (array.cycles, array.ledger());
        for i in 0..n {
            for j in 0..dims {
                array.load_row_bits_charged(
                    ds.rows.start + i,
                    layout.x[j].sign as usize,
                    33,
                    unpacked_bits(x[i * dims + j]),
                );
            }
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        DotKernel {
            layout,
            n,
            ds,
            load_stats,
        }
    }

    /// Device-model cost of the load phase (paid once per dataset).
    pub fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    /// Analytic cycle cost of one query — the per-repetition floor of a
    /// resident dataset. Exact: the microcode's shape depends only on the
    /// layout, never on H values.
    pub fn query_floor_cycles(&self) -> u64 {
        self.program(&vec![0.0f32; self.layout.dims]).cycle_estimate()
    }

    /// The full associative DP program for broadcast vector `h`
    /// (Fig. 8 lines 1–4, per attribute).
    pub fn program(&self, h: &[f32]) -> Program {
        let l = &self.layout;
        assert_eq!(h.len(), l.dims);
        let mut prog = Program::new();
        // acc := 0
        prog.push(Instr::SetTagsAll);
        let mut zero = l.acc.exp.pattern(0);
        zero.extend(l.acc.man.pattern(0));
        zero.push((l.acc.sign, false));
        prog.push(Instr::Write(zero));
        for j in 0..l.dims {
            // broadcast H_j
            prog.push(Instr::SetTagsAll);
            let bits = unpacked_bits(h[j]);
            let mut w = l.h.exp.pattern((bits >> 1) & 0xFF);
            w.extend(l.h.man.pattern(bits >> 9));
            w.push((l.h.sign, bits & 1 == 1));
            prog.push(Instr::Write(w));
            // Mult_j = x_j * H_j   (line 3)
            micro::float::fp_mul(&mut prog, l.x[j], l.h, l.mult, l.mul_scratch);
            // DP += Mult           (line 4): out = acc + mult, acc := out
            micro::float::fp_add(&mut prog, l.acc, l.mult, l.out, l.scratch, l.wexp);
            micro::copy_field_cond(&mut prog, l.out.exp, l.acc.exp, &vec![]);
            micro::copy_field_cond(&mut prog, l.out.man, l.acc.man, &vec![]);
            micro::shift::copy_col_cond(&mut prog, l.out.sign, l.acc.sign, &vec![]);
        }
        prog
    }

    /// One-shot alias for [`DotKernel::query`], kept for the
    /// load-and-run-once callers (CLI, figures, examples).
    pub fn run(&self, ctl: &mut Controller, sm: &StorageManager, h: &[f32]) -> DotResult {
        self.query(ctl, sm, h)
    }

    /// Query phase: execute the DP program for broadcast vector `h`
    /// against the resident vectors and read every result back. Charges
    /// only query cycles/energy; stored attribute fields are read-only to
    /// the program, so repeat queries are bit-identical.
    pub fn query(&self, ctl: &mut Controller, sm: &StorageManager, h: &[f32]) -> DotResult {
        ctl.begin_stats();
        let prog = self.program(h);
        ctl.execute(&prog);
        let l = &self.layout;
        let dp = (0..self.n)
            .map(|i| {
                bits_to_f32(ctl.array.fetch_row_bits(
                    sm.translate(&self.ds, i),
                    l.acc.sign as usize,
                    33,
                ))
            })
            .collect();
        DotResult {
            dp,
            stats: ctl.stats(),
        }
    }
}

/// Merged result of a DP query: global-row-order dot products plus the
/// protocol's checksum reply value.
pub struct DotOutput {
    /// Per-vector dot products in global row order, bit-identical to the
    /// single-device run (order-preserving concatenation merge).
    pub dp: Vec<f32>,
    /// Row-order f32 sum of `dp` (the protocol's checksum reply field).
    pub checksum: f32,
}

impl Kernel for DotKernel {
    type Data = FloatMatrix;
    type Params = Vec<f32>; // the broadcast hyperplane H
    type Output = Vec<f32>;

    const NAME: &'static str = "dp";
    const VERB: &'static str = "DP";
    const QUERY_ARITY: usize = 1;
    // the DP program writes scratch columns only (verified statically by
    // the `prins verify` overlay C03 contract), so queries run
    // concurrently through the scratch-overlay cursor
    const SHARED_READ: bool = true;

    fn data_rows(data: &FloatMatrix) -> usize {
        data.n
    }

    fn width(data: &FloatMatrix) -> usize {
        DotLayout::new(data.dims).width as usize
    }

    fn load_range(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        data: &FloatMatrix,
        range: Range<usize>,
    ) -> Self {
        DotKernel::load(sm, array, data.rows(&range), range.len(), data.dims)
    }

    fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    fn load_payload_bytes(&self) -> u64 {
        4 * (self.n * self.layout.dims) as u64
    }

    fn load_writes(&self) -> u64 {
        (self.n * self.layout.dims) as u64 // one write per stored attribute
    }

    fn resident_columns(&self) -> Range<u16> {
        // the D stored attributes; h/mult/acc/out are per-query scratch
        0..(self.layout.dims as u16 * 33)
    }

    fn query_shard(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        _range: &Range<usize>,
        params: &Vec<f32>,
    ) -> (Vec<f32>, ExecStats) {
        let res = self.query(ctl, sm, params);
        (res.dp, res.stats)
    }

    fn query_msg_bytes(&self, range: &Range<usize>, _params: &Vec<f32>) -> (u64, u64) {
        (4 * self.layout.dims as u64, 4 * range.len() as u64)
    }

    fn query_floor_cycles(&self, _array: &PrinsArray, _params: &Vec<f32>) -> u64 {
        self.query_floor_cycles() // the inherent floor (value-independent)
    }

    fn query_plan(&self, _array: &PrinsArray, params: &Vec<f32>) -> crate::analysis::QueryPlan {
        crate::analysis::QueryPlan {
            programs: vec![self.program(params)],
            extra_cycles: 0, // readout is storage-path, not kernel time
        }
    }

    fn params_key(&self, params: &Vec<f32>) -> Option<String> {
        // the program folds the H bits into its write keys, so the cache
        // key must carry the exact values
        let mut key = String::new();
        for h in params {
            key.push_str(&format!("{:08x}:", h.to_bits()));
        }
        Some(key)
    }

    fn query_shard_planned(
        &self,
        ctl: &mut Controller,
        sm: &StorageManager,
        _range: &Range<usize>,
        _params: &Vec<f32>,
        plan: &crate::analysis::QueryPlan,
    ) -> Option<(Vec<f32>, ExecStats)> {
        ctl.begin_stats();
        for prog in &plan.programs {
            ctl.execute(prog);
        }
        let l = &self.layout;
        let dp = (0..self.n)
            .map(|i| {
                bits_to_f32(ctl.array.fetch_row_bits(
                    sm.translate(&self.ds, i),
                    l.acc.sign as usize,
                    33,
                ))
            })
            .collect();
        Some((dp, ctl.stats()))
    }

    fn query_shard_overlay(
        &self,
        cur: &mut ReadCursor<'_>,
        sm: &StorageManager,
        _range: &Range<usize>,
        _params: &Vec<f32>,
        plan: &crate::analysis::QueryPlan,
    ) -> Option<(Vec<f32>, ExecStats)> {
        // mirror of query on the overlay cursor: execute the DP program,
        // then read every accumulator back overlay-first
        for prog in &plan.programs {
            cur.execute_overlay(prog).ok()?;
        }
        let l = &self.layout;
        let dp = (0..self.n)
            .map(|i| {
                bits_to_f32(cur.fetch_row_bits(
                    sm.translate(&self.ds, i),
                    l.acc.sign as usize,
                    33,
                ))
            })
            .collect();
        cur.add_cycles(plan.extra_cycles);
        Some((dp, cur.stats_microcoded()))
    }

    fn parse_params(&self, args: &[&str]) -> Result<Vec<f32>> {
        let seed: u64 = args[0].parse()?;
        Ok(synth_uniform(self.layout.dims, seed))
    }

    fn seeded_params(&self, q: usize, seed: u64) -> Vec<f32> {
        synth_uniform(self.layout.dims, seed + 1 + q as u64)
    }
}

impl ShardMerge for DotKernel {
    type Merged = DotOutput;

    fn merge(outputs: Vec<Vec<f32>>, _plan: &ShardPlan, _params: &Vec<f32>) -> DotOutput {
        let dp = merge_concat(&outputs);
        let checksum = dp.iter().sum();
        DotOutput { dp, checksum }
    }

    fn fields(merged: &DotOutput) -> String {
        format!("checksum={:.4}", merged.checksum)
    }

    fn bits(merged: &DotOutput) -> Vec<u64> {
        merged.dp.iter().map(|v| v.to_bits() as u64).collect()
    }
}

fn load_args(rack: &PrinsRack, args: &[&str]) -> Result<Box<dyn ResidentDyn>> {
    let [n, dims, seed] = args else {
        crate::error::bail!("usage: LOAD DP n dims seed");
    };
    let (n, dims, seed): (usize, usize, u64) = (n.parse()?, dims.parse()?, seed.parse()?);
    ensure!(
        n > 0 && n <= 1 << 16 && dims > 0 && dims <= 16,
        "size out of range"
    );
    let x = synth_samples(n, dims, 4, seed);
    let data = FloatMatrix::new(x, n, dims);
    Ok(Box::new(Resident::<DotKernel>::load(rack, &data)))
}

fn synth_load(rack: &PrinsRack, n: usize, dims: usize, seed: u64) -> Box<dyn ResidentDyn> {
    let dims = dims.clamp(1, 16);
    let data = FloatMatrix::new(synth_samples(n, dims, 4, seed), n, dims);
    Box::new(Resident::<DotKernel>::load(rack, &data))
}

fn one_shot(rack: &PrinsRack, args: &[&str]) -> Result<QueryOut> {
    let [n, dims, seed] = args else {
        crate::error::bail!("usage: DP n dims seed");
    };
    let (n, dims, seed): (usize, usize, u64) = (n.parse()?, dims.parse()?, seed.parse()?);
    ensure!(
        n > 0 && n <= 1 << 16 && dims > 0 && dims <= 16,
        "size out of range"
    );
    let data = FloatMatrix::new(synth_samples(n, dims, 4, seed), n, dims);
    let h = synth_uniform(dims, seed + 1);
    Ok(one_shot_out::<DotKernel>(rack, &data, &h))
}

/// The dot-product kernel's registry entry.
pub const ENTRY: KernelEntry = KernelEntry {
    name: DotKernel::NAME,
    verb: DotKernel::VERB,
    query_arity: DotKernel::QUERY_ARITY,
    one_shot_arity: 3,
    load_usage: "LOAD DP n dims seed",
    query_usage: "DP id seed",
    one_shot_usage: "DP n dims seed",
    dense: true,
    write_free_queries: false,
    overlay_queries: true,
    coalesce_queries: false,
    bits_f32: true,
    flops: |n, dims| 2.0 * (n * dims) as f64,
    load: load_args,
    synth_load,
    one_shot,
};

/// Deprecated pre-framework name for [`Resident<DotKernel>`].
#[deprecated(note = "use Resident<DotKernel> (algorithms::kernel)")]
pub type ResidentDot = Resident<DotKernel>;

/// Rack-sharded dot product, one-shot — a thin wrapper over the generic
/// framework ([`sharded`]); the merged result is on `.merged`. Copies
/// `x` once into an owned [`FloatMatrix`] (negligible next to the
/// simulated load); hot callers should build the matrix themselves and
/// use [`sharded`]/[`Resident`] directly.
pub fn dot_sharded(
    rack: &PrinsRack,
    x: &[f32],
    n: usize,
    dims: usize,
    h: &[f32],
) -> Sharded<DotKernel> {
    let data = FloatMatrix::new(x.to_vec(), n, dims);
    sharded::<DotKernel>(rack, &data, &h.to_vec())
}

/// Scalar CPU baseline.
pub fn dot_baseline(x: &[f32], n: usize, dims: usize, h: &[f32]) -> Vec<f32> {
    (0..n)
        .map(|i| (0..dims).map(|j| x[i * dims + j] * h[j]).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Rng;

    #[test]
    fn dp_matches_baseline() {
        let (n, dims) = (40usize, 4usize);
        let mut rng = Rng::seed_from(3);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let h: Vec<f32> = (0..dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let layout = DotLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = DotKernel::load(&mut sm, &mut array, &x, n, dims);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &sm, &h);
        let expect = dot_baseline(&x, n, dims, &h);
        for i in 0..n {
            assert!(
                (res.dp[i] - expect[i]).abs() <= 3e-5 * expect[i].abs().max(1.0),
                "dp[{i}]: {} vs {}",
                res.dp[i],
                expect[i]
            );
        }
    }

    #[test]
    fn resident_dp_queries_repeat_and_hit_floor() {
        let (n, dims) = (20usize, 3usize);
        let mut rng = Rng::seed_from(13);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let h1: Vec<f32> = (0..dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let h2: Vec<f32> = (0..dims).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let rack = PrinsRack::new(2);
        let data = FloatMatrix::new(x.clone(), n, dims);
        let mut res = Resident::<DotKernel>::load(&rack, &data);
        assert!(res.load_report().total_cycles > 0);
        let one_shot = dot_sharded(&rack, &x, n, dims, &h1);
        let qa = res.query(&h1);
        let qb = res.query(&h2); // different hyperplane on the same data
        let qc = res.query(&h1); // back to h1: bit-identical to the first
        assert!(one_shot
            .merged
            .dp
            .iter()
            .zip(&qa.merged.dp)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(qa
            .merged
            .dp
            .iter()
            .zip(&qc.merged.dp)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(qa.rack.total_cycles, qb.rack.total_cycles, "query cost is value-independent");
        // single-device floor check
        let layout = DotLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = DotKernel::load(&mut sm, &mut array, &x, n, dims);
        assert_eq!(kern.load_stats().cycles, 2 * (n * dims) as u64);
        let mut ctl = Controller::new(array);
        let r = kern.query(&mut ctl, &sm, &h1);
        assert_eq!(r.stats.cycles, kern.query_floor_cycles());
    }

    #[test]
    fn shared_overlay_dp_matches_the_exclusive_path_bitwise() {
        let (n, dims) = (28usize, 3usize);
        let mut rng = Rng::seed_from(23);
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-3.0, 3.0)).collect();
        let h: Vec<f32> = (0..dims).map(|_| rng.f32_range(-3.0, 3.0)).collect();
        let rack = PrinsRack::new(2);
        let data = FloatMatrix::new(x, n, dims);
        let mut res = Resident::<DotKernel>::load(&rack, &data);
        assert!(res.shared_readable(), "dp opts into the shared-read path");
        let wear0 = res.shard_wear();
        let shared = res.query_shared(&h).expect("shared path refused");
        assert_eq!(res.shard_wear(), wear0, "shared query advanced wear");
        let excl = res.query(&h);
        assert!(shared
            .merged
            .dp
            .iter()
            .zip(&excl.merged.dp)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(
            shared.merged.checksum.to_bits(),
            excl.merged.checksum.to_bits()
        );
        assert_eq!(shared.rack.total_cycles, excl.rack.total_cycles);
        assert_eq!(shared.rack.link_bytes, excl.rack.link_bytes);
        assert_eq!(shared.rack.shard_stats, excl.rack.shard_stats);
    }

    #[test]
    fn dp_cycles_independent_of_vector_count() {
        let dims = 2;
        let layout = DotLayout::new(dims);
        let run_n = |n: usize| -> u64 {
            let mut rng = Rng::seed_from(9);
            let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let mut array = PrinsArray::single(n, layout.width as usize);
            let mut sm = StorageManager::new(n);
            let kern = DotKernel::load(&mut sm, &mut array, &x, n, dims);
            let mut ctl = Controller::new(array);
            kern.run(&mut ctl, &sm, &[0.3, -0.7]).stats.cycles
        };
        assert_eq!(run_n(8), run_n(128));
    }
}
