//! Algorithm 4 (paper Fig. 10): fully associative SpMV, y = A·x.
//!
//! One CSR nonzero per RCAM row: (row index, column index, value).
//! Three phases, exactly the paper's:
//!
//!  1. **Broadcast** — for each element x_j: one compare of j against the
//!     column-index field (tags every nonzero in column j) and one write
//!     of x_j next to those nonzeros. O(n) serial over the vector, each
//!     step hitting all matching nonzeros at once.
//!  2. **Multiply** — one fixed-point multiply microprogram computes
//!     e_A · x_col for ALL nonzeros in parallel (the number of
//!     simultaneous multiplications equals nnz — the paper's parallelism
//!     claim).
//!  3. **Reduce** — per-row summation. Two interchangeable engines:
//!     * `ChainTree` (default): segmented Hillis–Steele suffix scan over
//!       the daisy-chain interconnect, log₂(max row length) levels, all
//!       rows in parallel — the method of the paper's companion [79].
//!     * `SerialTree`: the literal per-matrix-row reduction-tree loop of
//!       Fig. 10 lines 5–6 (O(n) reduce issues). Kept as an ablation;
//!       `ablation_microcode` quantifies the gap.
//!
//! Numerics: values are quantized to Q1.14 sign-magnitude (the paper's
//! reduction tree sums *bits*, so PRINS SpMV is fixed-point here;
//! substitution ledger in DESIGN.md). Products are Q2.28 in a 48-bit
//! two's-complement accumulator.

use crate::algorithms::kernel::{
    one_shot_out, sharded, Kernel, KernelEntry, QueryOut, Resident, ResidentDyn, ShardMerge,
    Sharded,
};
use crate::controller::{Controller, ExecStats};
use crate::error::{ensure, Result};
use crate::host::rack::PrinsRack;
use crate::isa::{Field, Instr, Program, RowLayout};
use crate::micro;
use crate::rcam::shard::{merge_concat, ShardPlan};
use crate::rcam::{ExecBackend, PrinsArray};
use crate::storage::{Dataset, StorageManager};
use crate::workloads::{synth_csr, Csr, Rng};
use std::ops::Range;

/// Fraction bits of the Q1.14 operands.
pub const QFRAC: u32 = 14;
/// Fraction bits of the Q2.28 products.
pub const PFRAC: u32 = 2 * QFRAC;

/// Which of the two interchangeable per-row reduction engines runs
/// phase 3 (see the module doc).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceEngine {
    /// Segmented chain scan ([79]-style, all rows parallel).
    ChainTree,
    /// Paper Fig. 10 literal: per-row reduction-tree sweep.
    SerialTree,
}

/// Quantize to Q1.14 sign-magnitude (sign bit, 15-bit magnitude).
pub fn quantize(v: f32) -> (bool, u64) {
    let clamped = v.clamp(-1.999, 1.999);
    let mag = (clamped.abs() * (1 << QFRAC) as f32).round() as u64;
    (clamped < 0.0, mag.min((1 << 15) - 1))
}

/// Convert a Q2.28 product accumulator back to f32.
pub fn dequantize_product(acc: i64) -> f32 {
    acc as f32 / (1u64 << PFRAC) as f32
}

/// Row layout (≤ 256 bits):
///   rowid(24) | colid(24) | a_sign(1) a_mag(15) | b_sign(1) b_mag(15)
///   | pmag(30) | prod(48 two's complement) | nb_rowid(24) | nb_prod(48)
///   | flags/carry (6)
pub struct SpmvLayout {
    /// Matrix-row index of this nonzero.
    pub rowid: Field,
    /// Column index of this nonzero.
    pub colid: Field,
    /// Sign bit of the matrix value (sign-magnitude Q1.14).
    pub a_sign: u16,
    /// Magnitude of the matrix value.
    pub a_mag: Field,
    /// Sign bit of the broadcast x value.
    pub b_sign: u16,
    /// Magnitude of the broadcast x value.
    pub b_mag: Field,
    /// Unsigned product magnitude (Q2.28).
    pub pmag: Field,
    /// Signed product / running row sum (48-bit two's complement).
    pub prod: Field,
    /// Chain-shifted neighbour rowid (reduction scan operand).
    pub nb_rowid: Field,
    /// Chain-shifted neighbour product (reduction scan operand).
    pub nb_prod: Field,
    /// Carry flag column of the adder microcode.
    pub carry: u16,
    /// Product sign flag (`a_sign ⊕ b_sign`).
    pub psign: u16,
    /// Staging flag of the conditional negate.
    pub tmp: u16,
    /// Equality flag of the segmented-scan rowid compare.
    pub eq: u16,
    /// Less-than flag of the rowid compare (unused side output).
    pub lt: u16,
    /// Total columns the layout occupies.
    pub width: u16,
}

impl SpmvLayout {
    /// Lay the fields out contiguously (≤ 256 bits, asserted by `check`).
    pub fn new() -> Self {
        let mut base = 0u16;
        let mut next = |w: u16| {
            let b = base;
            base += w;
            b
        };
        let l = SpmvLayout {
            rowid: Field::new(next(24), 24),
            colid: Field::new(next(24), 24),
            a_sign: next(1),
            a_mag: Field::new(next(15), 15),
            b_sign: next(1),
            b_mag: Field::new(next(15), 15),
            pmag: Field::new(next(30), 30),
            prod: Field::new(next(48), 48),
            nb_rowid: Field::new(next(24), 24),
            nb_prod: Field::new(next(48), 48),
            carry: next(1),
            psign: next(1),
            tmp: next(1),
            eq: next(1),
            lt: next(1),
            width: 0,
        };
        SpmvLayout { width: base, ..l }
    }

    /// The contiguous (rowid, prod) source/dest regions must mirror each
    /// other for the chain shift; assert the invariant.
    fn check(&self) {
        assert!(self.width <= 256, "spmv layout exceeds 256-bit rows");
    }
}

impl Default for SpmvLayout {
    fn default() -> Self {
        Self::new()
    }
}

/// Result of one SpMV run, with per-phase cycle accounting.
pub struct SpmvResult {
    /// `y = A·x`, dequantized, one entry per matrix row.
    pub y: Vec<f32>,
    /// Execution statistics of the whole run.
    pub stats: ExecStats,
    /// Cycles of phase 1 (x broadcast, 3 per vector element).
    pub broadcast_cycles: u64,
    /// Cycles of phase 2 (all-rows fixed-point multiply).
    pub multiply_cycles: u64,
    /// Cycles of phase 3 (per-row reduction).
    pub reduce_cycles: u64,
}

/// Loaded SpMV dataset (one CSR nonzero per row) + phase programs.
///
/// Load-once / query-many: [`SpmvKernel::load`] writes the CSR nonzeros
/// into RCAM rows once (charged, [`SpmvKernel::load_stats`]); each
/// [`SpmvKernel::query`] broadcasts a fresh x vector against the
/// resident nonzeros and charges only query cycles/energy. The stored
/// fields (rowid, colid, value) are read-only to every phase — broadcast
/// writes b fields, multiply/reduce write work areas — so repeat queries
/// are bit-identical.
pub struct SpmvKernel {
    /// The row layout in use.
    pub layout: SpmvLayout,
    /// Loaded nonzero count.
    pub nnz: usize,
    /// Matrix dimension (rows of A, length of x and y).
    pub n: usize,
    max_row_nnz: usize,
    /// physical row of the first nonzero of each matrix row (readout)
    row_heads: Vec<Option<usize>>,
    /// allocation handle pinning the rows (readout goes via row_heads)
    #[allow(dead_code)]
    ds: Dataset,
    load_stats: ExecStats,
}

impl SpmvKernel {
    /// Allocate rows and load every CSR nonzero as (rowid, colid,
    /// quantized value) — four charged row writes per nonzero.
    pub fn load(sm: &mut StorageManager, array: &mut PrinsArray, a: &Csr) -> Self {
        let layout = SpmvLayout::new();
        layout.check();
        assert!(array.width() >= layout.width as usize);
        assert!(a.n < (1 << 24), "rowid field is 24 bits");
        let nnz = a.nnz();
        let ds = sm
            .alloc(nnz, RowLayout::new(layout.width))
            .expect("storage full");
        let mut row_heads = vec![None; a.n];
        let mut k = 0usize;
        let (c0, l0) = (array.cycles, array.ledger());
        for (r, c, v) in a.triplets() {
            let phys = ds.rows.start + k;
            if row_heads[r as usize].is_none() {
                row_heads[r as usize] = Some(phys);
            }
            array.load_row_bits_charged(phys, layout.rowid.base as usize, 24, r as u64);
            array.load_row_bits_charged(phys, layout.colid.base as usize, 24, c as u64);
            let (s, m) = quantize(v);
            array.load_row_bits_charged(phys, layout.a_sign as usize, 1, s as u64);
            array.load_row_bits_charged(phys, layout.a_mag.base as usize, 15, m);
            k += 1;
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        SpmvKernel {
            layout,
            nnz,
            n: a.n,
            max_row_nnz: a.max_row_nnz(),
            row_heads,
            ds,
            load_stats,
        }
    }

    /// Device-model cost of the load phase (paid once per dataset).
    pub fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    /// Analytic cycle cost of one [`ReduceEngine::ChainTree`] query — the
    /// per-repetition floor of a resident dataset: 3 cycles per broadcast
    /// element, the multiply microprogram, and per scan level two
    /// `2^k`-hop chain moves plus the compare/add level program. Exact
    /// (the microcode's shape depends only on the layout, never on x).
    pub fn query_floor_cycles(&self) -> u64 {
        let broadcast = 3 * self.n as u64;
        let multiply = self.multiply_program().cycle_estimate();
        let levels = self.max_row_nnz.max(2).next_power_of_two().ilog2() as u64;
        let level_prog = self.reduce_level_program().cycle_estimate();
        // Σ_{k<levels} 2·2^k hop cycles (two 2^k-hop field moves per level)
        let hops = 2 * ((1u64 << levels) - 1);
        broadcast + multiply + levels * level_prog + hops
    }

    /// Phase 1 (Fig. 10 lines 1–3) as a program: per vector element one
    /// compare of j against all column indices, one write of e_B into
    /// the matching rows. Shared by [`SpmvKernel::query`] and the static
    /// analyzer's [`Kernel::query_plan`] view.
    fn broadcast_program(&self, x: &[f32]) -> Program {
        let l = &self.layout;
        let mut prog = Program::new();
        for (j, &xv) in x.iter().enumerate() {
            let (s, m) = quantize(xv);
            // line 2: compare i_B with all column indices
            prog.push(Instr::Compare(l.colid.pattern(j as u64)));
            // line 3: write e_B into all matching rows
            let mut w = l.b_mag.pattern(m);
            w.push((l.b_sign, s));
            prog.push(Instr::Write(w));
        }
        prog
    }

    /// Phase 1 (Fig. 10 lines 1–3): broadcast x into the b fields.
    fn broadcast(&self, ctl: &mut Controller, x: &[f32]) {
        ctl.execute(&self.broadcast_program(x));
    }

    /// Phase 2 (Fig. 10 line 4): PR ← e_B · e_A for all nonzeros at once.
    fn multiply_program(&self) -> Program {
        let l = &self.layout;
        let mut prog = Program::new();
        micro::mul(&mut prog, l.a_mag, l.b_mag, l.pmag, l.carry);
        // prod := (a_sign ^ b_sign) ? -pmag : +pmag, two's complement 48b
        let t = micro::TruthTable::from_fn(
            vec![l.a_sign, l.b_sign],
            vec![l.psign],
            |i| vec![i[0] ^ i[1]],
        );
        t.emit(&mut prog, true);
        prog.clear_field(l.prod);
        micro::copy_field_cond(&mut prog, l.pmag, l.prod.slice(0, 30), &vec![]);
        // conditional negate where psign == 1 (staged via tmp)
        micro::sub::neg_inplace_cond(&mut prog, l.prod, l.carry, l.tmp, &vec![(l.psign, true)]);
        prog
    }

    /// One level of the segmented chain scan: eq := (rowid == nb_rowid),
    /// then prod += nb_prod where eq. Identical at every level (only the
    /// chain-hop distance changes, and that is an array move, not a
    /// program) — shared by `reduce_chain` and the analytic query floor.
    fn reduce_level_program(&self) -> Program {
        let l = &self.layout;
        let mut prog = Program::new();
        // eq := (rowid == nb_rowid)
        micro::field_cmp(&mut prog, l.rowid, l.nb_rowid, l.lt, l.eq);
        // prod += nb_prod where eq (two's complement: signs included)
        micro::add_inplace_cond(&mut prog, l.prod, l.nb_prod, l.carry, &vec![(l.eq, true)]);
        prog
    }

    /// Phase 3a: segmented suffix scan over the daisy chain.
    fn reduce_chain(&self, ctl: &mut Controller) {
        let l = &self.layout;
        let levels = self.max_row_nnz.max(2).next_power_of_two().ilog2();
        let prog = self.reduce_level_program();
        for k in 0..levels {
            let hops = 1usize << k;
            // neighbor fields := (rowid, prod) shifted down by `hops`
            ctl.array
                .shift_columns_to(l.rowid.base, l.nb_rowid.base, 24, hops);
            ctl.array
                .shift_columns_to(l.prod.base, l.nb_prod.base, 48, hops);
            ctl.execute(&prog);
        }
    }

    /// Phase 3b: the literal Fig. 10 lines 5–6 per-row reduction sweep.
    /// Positive and negative products are tallied separately (the tree
    /// sums tag bits); the controller subtracts.
    fn reduce_serial(&self, ctl: &mut Controller) -> Vec<i64> {
        let l = &self.layout;
        let mut sums = vec![0i64; self.n];
        for (r, head) in self.row_heads.iter().enumerate() {
            if head.is_none() {
                continue;
            }
            let mut prog = Program::new();
            // tag positive-product nonzeros of row r, sum magnitude planes
            let mut pat = l.rowid.pattern(r as u64);
            pat.push((l.psign, false));
            prog.push(Instr::Compare(pat));
            micro::emit_field_sum(&mut prog, l.pmag);
            let pos = ctl.execute_collect(&prog);
            let mut prog = Program::new();
            let mut pat = l.rowid.pattern(r as u64);
            pat.push((l.psign, true));
            prog.push(Instr::Compare(pat));
            micro::emit_field_sum(&mut prog, l.pmag);
            let neg = ctl.execute_collect(&prog);
            sums[r] = micro::combine_field_sum(&pos) as i64
                - micro::combine_field_sum(&neg) as i64;
        }
        ctl.array.charge_reduction_latency();
        sums
    }

    /// One-shot alias for [`SpmvKernel::query`], kept for the
    /// load-and-run-once callers (CLI, figures, examples).
    pub fn run(&self, ctl: &mut Controller, x: &[f32], engine: ReduceEngine) -> SpmvResult {
        self.query(ctl, x, engine)
    }

    /// Query phase: full SpMV for a fresh `x` against the resident CSR
    /// nonzeros. Returns y plus per-phase cycle accounting; charges only
    /// query cycles/energy (stored rowid/colid/value fields are read-only
    /// to every phase, so repeat queries are bit-identical).
    pub fn query(&self, ctl: &mut Controller, x: &[f32], engine: ReduceEngine) -> SpmvResult {
        assert_eq!(x.len(), self.n);
        ctl.begin_stats();
        let c0 = ctl.array.cycles;
        self.broadcast(ctl, x);
        let c1 = ctl.array.cycles;
        let prog = self.multiply_program();
        ctl.execute(&prog);
        let c2 = ctl.array.cycles;
        let y = match engine {
            ReduceEngine::ChainTree => {
                self.reduce_chain(ctl);
                // readout: first nonzero of each row holds the row sum
                self.row_heads
                    .iter()
                    .map(|h| match h {
                        Some(phys) => {
                            let bits = ctl.array.fetch_row_bits(
                                *phys,
                                self.layout.prod.base as usize,
                                48,
                            );
                            // sign-extend 48 bits
                            let v = ((bits << 16) as i64) >> 16;
                            dequantize_product(v)
                        }
                        None => 0.0,
                    })
                    .collect()
            }
            ReduceEngine::SerialTree => self
                .reduce_serial(ctl)
                .into_iter()
                .map(dequantize_product)
                .collect(),
        };
        let c3 = ctl.array.cycles;
        SpmvResult {
            y,
            stats: ctl.stats(),
            broadcast_cycles: c1 - c0,
            multiply_cycles: c2 - c1,
            reduce_cycles: c3 - c2,
        }
    }
}

/// Single-device convenience driver: size an array for `a`'s nonzeros,
/// load it, and run with the chain-scan reduce engine. The CLI and the
/// TCP server both drive single-device SpMV through this, so their
/// results cannot diverge.
pub fn spmv_single(a: &Csr, x: &[f32], backend: ExecBackend) -> SpmvResult {
    let mut array = PrinsArray::single(a.nnz(), 256).with_backend(backend);
    let mut sm = StorageManager::new(a.nnz());
    let kern = SpmvKernel::load(&mut sm, &mut array, a);
    let mut ctl = Controller::new(array);
    kern.run(&mut ctl, x, ReduceEngine::ChainTree)
}

/// Merged result of an SpMV query: `y = A·x` in global row order plus
/// the protocol's checksum reply value.
pub struct SpmvOutput {
    /// `y = A·x` in global row order, bit-identical to the single-device
    /// run (each matrix row lives entirely in one shard, so the merge is
    /// an order-preserving scatter of per-shard row slices).
    pub y: Vec<f32>,
    /// Row-order f32 sum of `y` (the protocol's checksum reply field).
    pub checksum: f32,
}

impl Kernel for SpmvKernel {
    type Data = Csr;
    type Params = Vec<f32>; // the broadcast x vector
    type Output = Vec<f32>; // this shard's y slice

    const NAME: &'static str = "spmv";
    const VERB: &'static str = "SPMV";
    const QUERY_ARITY: usize = 1;

    fn data_rows(data: &Csr) -> usize {
        data.n
    }

    /// Nonzero-balanced contiguous row cuts ([`ShardPlan::weighted`])
    /// so no matrix row splits across shards and the chain reduce stays
    /// shard-local.
    fn plan(data: &Csr, shards: usize) -> ShardPlan {
        ShardPlan::weighted(&data.row_nnz(), shards)
    }

    fn width(_data: &Csr) -> usize {
        256
    }

    fn shard_rows(data: &Csr, range: &Range<usize>) -> usize {
        data.row_nnz()[range.clone()].iter().sum()
    }

    fn load_range(
        sm: &mut StorageManager,
        array: &mut PrinsArray,
        data: &Csr,
        range: Range<usize>,
    ) -> Self {
        SpmvKernel::load(sm, array, &data.mask_rows(range))
    }

    fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    fn load_payload_bytes(&self) -> u64 {
        12 * self.nnz as u64 // (rowid, colid, value) per CSR nonzero
    }

    fn load_writes(&self) -> u64 {
        4 * self.nnz as u64 // rowid, colid, sign, magnitude per nonzero
    }

    fn resident_columns(&self) -> Range<u16> {
        // rowid | colid | a_sign | a_mag hold the matrix; everything
        // from b_sign on is per-query broadcast/scratch
        self.layout.rowid.base..(self.layout.a_mag.base + self.layout.a_mag.width)
    }

    fn query_shard(
        &self,
        ctl: &mut Controller,
        _sm: &StorageManager,
        range: &Range<usize>,
        params: &Vec<f32>,
    ) -> (Vec<f32>, ExecStats) {
        let res = self.query(ctl, params, ReduceEngine::ChainTree);
        (res.y[range.clone()].to_vec(), res.stats)
    }

    fn query_msg_bytes(&self, range: &Range<usize>, _params: &Vec<f32>) -> (u64, u64) {
        (4 * self.n as u64, 4 * range.len() as u64)
    }

    fn query_floor_cycles(&self, _array: &PrinsArray, _params: &Vec<f32>) -> u64 {
        self.query_floor_cycles() // the inherent ChainTree floor
    }

    fn query_plan(&self, _array: &PrinsArray, params: &Vec<f32>) -> crate::analysis::QueryPlan {
        let levels = self.max_row_nnz.max(2).next_power_of_two().ilog2() as u64;
        let mut programs = vec![self.broadcast_program(params), self.multiply_program()];
        programs.extend((0..levels).map(|_| self.reduce_level_program()));
        crate::analysis::QueryPlan {
            programs,
            // the per-level (rowid, prod) chain moves are array moves,
            // not program instructions: Σ_{k<levels} 2·2^k hop cycles
            extra_cycles: 2 * ((1u64 << levels) - 1),
        }
    }

    fn parse_params(&self, args: &[&str]) -> Result<Vec<f32>> {
        let seed: u64 = args[0].parse()?;
        let mut rng = Rng::seed_from(seed);
        Ok((0..self.n).map(|_| rng.f32_range(-1.0, 1.0)).collect())
    }

    fn seeded_params(&self, q: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed + 1 + q as u64);
        (0..self.n).map(|_| rng.f32_range(-1.0, 1.0)).collect()
    }
}

impl ShardMerge for SpmvKernel {
    type Merged = SpmvOutput;

    fn merge(outputs: Vec<Vec<f32>>, plan: &ShardPlan, _params: &Vec<f32>) -> SpmvOutput {
        let y = merge_concat(&outputs);
        debug_assert_eq!(y.len(), plan.total_rows());
        let checksum = y.iter().sum();
        SpmvOutput { y, checksum }
    }

    fn fields(merged: &SpmvOutput) -> String {
        format!("checksum={:.4}", merged.checksum)
    }

    fn bits(merged: &SpmvOutput) -> Vec<u64> {
        merged.y.iter().map(|v| v.to_bits() as u64).collect()
    }
}

fn load_args(rack: &PrinsRack, args: &[&str]) -> Result<Box<dyn ResidentDyn>> {
    let [n, nnz, seed] = args else {
        crate::error::bail!("usage: LOAD SPMV n nnz seed");
    };
    let (n, nnz, seed): (usize, usize, u64) = (n.parse()?, nnz.parse()?, seed.parse()?);
    ensure!(
        n > 0 && n <= 1 << 14 && nnz > 0 && nnz <= 1 << 18,
        "size out of range"
    );
    let a = synth_csr(n, nnz, seed);
    Ok(Box::new(Resident::<SpmvKernel>::load(rack, &a)))
}

fn synth_load(rack: &PrinsRack, n: usize, _dims: usize, seed: u64) -> Box<dyn ResidentDyn> {
    let a = synth_csr(n, n * 8, seed);
    Box::new(Resident::<SpmvKernel>::load(rack, &a))
}

fn one_shot(rack: &PrinsRack, args: &[&str]) -> Result<QueryOut> {
    let [n, nnz, seed] = args else {
        crate::error::bail!("usage: SPMV n nnz seed");
    };
    let (n, nnz, seed): (usize, usize, u64) = (n.parse()?, nnz.parse()?, seed.parse()?);
    ensure!(
        n > 0 && n <= 1 << 14 && nnz > 0 && nnz <= 1 << 18,
        "size out of range"
    );
    let a = synth_csr(n, nnz, seed);
    let mut rng = Rng::seed_from(seed + 1);
    let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    Ok(one_shot_out::<SpmvKernel>(rack, &a, &x))
}

/// The SpMV kernel's registry entry.
pub const ENTRY: KernelEntry = KernelEntry {
    name: SpmvKernel::NAME,
    verb: SpmvKernel::VERB,
    query_arity: SpmvKernel::QUERY_ARITY,
    one_shot_arity: 3,
    load_usage: "LOAD SPMV n nnz seed",
    query_usage: "SPMV id seed",
    one_shot_usage: "SPMV n nnz seed",
    dense: true,
    write_free_queries: false,
    overlay_queries: false,
    coalesce_queries: false,
    bits_f32: true,
    flops: |n, _dims| 2.0 * (n * 8) as f64, // synth density: 8 nnz per row
    load: load_args,
    synth_load,
    one_shot,
};

/// Deprecated pre-framework name for [`Resident<SpmvKernel>`].
#[deprecated(note = "use Resident<SpmvKernel> (algorithms::kernel)")]
pub type ResidentSpmv = Resident<SpmvKernel>;

/// Rack-sharded SpMV, one-shot — a thin wrapper over the generic
/// framework ([`sharded`]); the merged result is on `.merged`. Copies
/// `x` once into the owned params vector (negligible next to the
/// simulated load).
pub fn spmv_sharded(rack: &PrinsRack, a: &Csr, x: &[f32]) -> Sharded<SpmvKernel> {
    sharded::<SpmvKernel>(rack, a, &x.to_vec())
}

/// Quantized scalar baseline (bit-exact vs the associative fixed-point
/// pipeline, up to identical quantization).
pub fn spmv_baseline_quantized(a: &Csr, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0f32; a.n];
    for (r, c, v) in a.triplets() {
        let (sa, ma) = quantize(v);
        let (sb, mb) = quantize(x[c as usize]);
        let p = (ma * mb) as i64;
        let p = if sa ^ sb { -p } else { p };
        y[r as usize] += dequantize_product(p);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{synth_csr, Rng};

    fn setup(n: usize, nnz: usize, seed: u64) -> (Csr, Vec<f32>) {
        let a = synth_csr(n, nnz, seed);
        let mut rng = Rng::seed_from(seed + 1);
        let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        (a, x)
    }

    #[test]
    fn chain_reduce_matches_quantized_baseline() {
        let (a, x) = setup(64, 500, 5);
        let mut array = PrinsArray::new(4, (a.nnz() + 3) / 4 + 1, 256);
        let mut sm = StorageManager::new(array.total_rows());
        let kern = SpmvKernel::load(&mut sm, &mut array, &a);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &x, ReduceEngine::ChainTree);
        let expect = spmv_baseline_quantized(&a, &x);
        for r in 0..a.n {
            assert!(
                (res.y[r] - expect[r]).abs() < 1e-6,
                "row {r}: {} vs {}",
                res.y[r],
                expect[r]
            );
        }
        // quantization error vs float reference stays bounded
        let float_ref = a.spmv(&x);
        for r in 0..a.n {
            assert!((res.y[r] - float_ref[r]).abs() < 1e-2, "row {r} float drift");
        }
    }

    #[test]
    fn serial_reduce_matches_chain_reduce() {
        // n large enough that the O(n)-sweep serial engine loses to the
        // O(log maxrow) chain scan (tiny n favours the serial engine)
        let (a, x) = setup(256, 1400, 9);
        let run = |engine| {
            let mut array = PrinsArray::single(a.nnz(), 256);
            let mut sm = StorageManager::new(a.nnz());
            let kern = SpmvKernel::load(&mut sm, &mut array, &a);
            let mut ctl = Controller::new(array);
            kern.run(&mut ctl, &x, engine)
        };
        let chain = run(ReduceEngine::ChainTree);
        let serial = run(ReduceEngine::SerialTree);
        for r in 0..a.n {
            assert!(
                (chain.y[r] - serial.y[r]).abs() < 1e-6,
                "row {r}: {} vs {}",
                chain.y[r],
                serial.y[r]
            );
        }
        // the chain engine's reduce phase must be asymptotically cheaper
        assert!(chain.reduce_cycles < serial.reduce_cycles);
    }

    #[test]
    fn resident_spmv_queries_repeat_and_hit_floor() {
        let (a, x) = setup(48, 320, 15);
        let mut rng = Rng::seed_from(16);
        let x2: Vec<f32> = (0..a.n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let rack = PrinsRack::new(2);
        let mut res = Resident::<SpmvKernel>::load(&rack, &a);
        assert!(res.load_report().total_cycles > 0, "load phase is charged");
        let one_shot = spmv_sharded(&rack, &a, &x);
        let qa = res.query(&x);
        let qb = res.query(&x2); // new x-vector on the same matrix
        let qc = res.query(&x); // back to x: bit-identical to the first
        assert!(one_shot
            .merged
            .y
            .iter()
            .zip(&qa.merged.y)
            .all(|(p, q)| p.to_bits() == q.to_bits()));
        assert!(qa
            .merged
            .y
            .iter()
            .zip(&qc.merged.y)
            .all(|(p, q)| p.to_bits() == q.to_bits()));
        assert_eq!(qa.rack.total_cycles, qb.rack.total_cycles, "query cost is value-independent");
        // single-device floor check
        let mut array = PrinsArray::single(a.nnz(), 256);
        let mut sm = StorageManager::new(a.nnz());
        let kern = SpmvKernel::load(&mut sm, &mut array, &a);
        assert_eq!(kern.load_stats().cycles, 2 * 4 * a.nnz() as u64);
        let mut ctl = Controller::new(array);
        let r = kern.query(&mut ctl, &x, ReduceEngine::ChainTree);
        assert_eq!(r.stats.cycles, kern.query_floor_cycles());
    }

    #[test]
    fn multiply_phase_cost_independent_of_nnz() {
        let (a1, x1) = setup(32, 100, 11);
        let (a2, x2) = setup(32, 400, 12);
        let run = |a: &Csr, x: &[f32]| {
            let mut array = PrinsArray::single(a.nnz(), 256);
            let mut sm = StorageManager::new(a.nnz());
            let kern = SpmvKernel::load(&mut sm, &mut array, a);
            let mut ctl = Controller::new(array);
            kern.run(&mut ctl, x, ReduceEngine::ChainTree).multiply_cycles
        };
        assert_eq!(run(&a1, &x1), run(&a2, &x2));
    }

    #[test]
    fn broadcast_cost_is_3_cycles_per_element() {
        let (a, x) = setup(40, 200, 13);
        let mut array = PrinsArray::single(a.nnz(), 256);
        let mut sm = StorageManager::new(a.nnz());
        let kern = SpmvKernel::load(&mut sm, &mut array, &a);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &x, ReduceEngine::ChainTree);
        assert_eq!(res.broadcast_cycles, 3 * a.n as u64);
    }
}
