//! Algorithm 5 (paper Fig. 11 + Table 2): serial associative BFS.
//!
//! One *edge* per RCAM row, Table 2 format (IDs narrowed to 24 bits so the
//! row fits 256 columns; the paper's 48-bit IDs would need 512-bit rows):
//!
//!   vertexID | successorID | visited | visited_from | predecessorID | distance
//!
//! The implementation is the paper's *literal* serial loop: pick one
//! unexpanded frontier edge (first_match), mark it expanded, read its
//! successor, then update ALL of the successor's edge rows in one
//! compare+write (the associative win: a vertex's whole adjacency state
//! updates in O(1) regardless of its degree).
//!
//! The paper's Fig. 14 numbers additionally assume vertex-granular
//! serialization ("vertices are examined serially and speedup is limited
//! by the average out-degree"); `paper_model_teps` reproduces that
//! analytical model, and EXPERIMENTS.md discusses the gap between it and
//! the literal algorithm measured here.

use crate::controller::{Controller, ExecStats, READ_NO_MATCH};
use crate::isa::{Field, Instr, RowLayout};
use crate::rcam::PrinsArray;
use crate::storage::{Dataset, StorageManager};
use crate::workloads::Graph;

/// "unvisited" distance sentinel (the all-ones 16-bit pattern).
pub const DIST_INF: u64 = 0xFFFF;

/// Row layout of one edge record (paper Table 2, 24-bit IDs).
pub struct BfsLayout {
    /// Source vertex ID of the edge.
    pub vertex: Field,
    /// Successor (destination) vertex ID.
    pub succ: Field,
    /// Vertex-visited flag (set on every edge row of the vertex).
    pub visited: u16,
    /// Edge-expanded flag (this row already served as a frontier edge).
    pub visited_from: u16,
    /// BFS-tree predecessor vertex ID.
    pub pred: Field,
    /// BFS distance of the source vertex ([`DIST_INF`] = unvisited).
    pub dist: Field,
    /// dataset-membership flag (unloaded rows must never join a frontier)
    pub valid: u16,
    /// Total columns the layout occupies.
    pub width: u16,
}

impl BfsLayout {
    /// The Table 2 layout with 24-bit IDs and a 16-bit distance.
    pub fn new() -> Self {
        // Table 2, with 24-bit IDs and a 16-bit distance
        BfsLayout {
            vertex: Field::new(0, 24),
            succ: Field::new(24, 24),
            visited: 48,
            visited_from: 49,
            pred: Field::new(50, 24),
            dist: Field::new(74, 16),
            valid: 90,
            width: 91,
        }
    }
}

impl Default for BfsLayout {
    fn default() -> Self {
        Self::new()
    }
}

/// Result of one BFS run.
pub struct BfsResult {
    /// distance per vertex (u32::MAX = unreachable / no out-edges)
    pub dist: Vec<u32>,
    /// Execution statistics of the run.
    pub stats: ExecStats,
    /// serial loop iterations (edge expansions)
    pub iterations: u64,
    /// Number of BFS levels traversed.
    pub levels: u32,
}

/// Loaded BFS edge list + the serial associative traversal loop.
///
/// BFS has the standard `load`/`load_stats`/`query` split of the other
/// kernels, but it is **not** in the kernel registry
/// ([`crate::algorithms::kernel::registry`]): its query mutates the
/// resident rows (the frontier is written back into the `visited`/
/// `visited_from`/`dist` fields), so the framework's load-once /
/// query-many and shard-merge contracts — which require queries to leave
/// stored fields untouched — do not hold. A second [`BfsKernel::query`]
/// sees the first query's frontier state; callers must reload first.
pub struct BfsKernel {
    /// The row layout in use.
    pub layout: BfsLayout,
    /// Vertex count of the loaded graph.
    pub n_vertices: usize,
    /// Edge count of the loaded graph (rows in storage).
    pub n_edges: usize,
    head_row: Vec<Option<usize>>,
    /// allocation handle pinning the rows (readout goes via head_row)
    #[allow(dead_code)]
    ds: Dataset,
    load_stats: ExecStats,
}

impl BfsKernel {
    /// Allocate rows and load every edge as a Table 2 record — four
    /// charged row writes per edge (vertex, successor, distance
    /// sentinel, valid bit).
    pub fn load(sm: &mut StorageManager, array: &mut PrinsArray, g: &Graph) -> Self {
        let layout = BfsLayout::new();
        assert!(array.width() >= layout.width as usize);
        assert!(g.n < (1 << 24));
        let edges = g.edge_list();
        let ds = sm
            .alloc(edges.len(), RowLayout::new(layout.width))
            .expect("storage full");
        let mut head_row = vec![None; g.n];
        let (c0, l0) = (array.cycles, array.ledger());
        for (k, &(u, v)) in edges.iter().enumerate() {
            let phys = ds.rows.start + k;
            if head_row[u as usize].is_none() {
                head_row[u as usize] = Some(phys);
            }
            array.load_row_bits_charged(phys, layout.vertex.base as usize, 24, u as u64);
            array.load_row_bits_charged(phys, layout.succ.base as usize, 24, v as u64);
            array.load_row_bits_charged(phys, layout.dist.base as usize, 16, DIST_INF);
            array.load_row_bits_charged(phys, layout.valid as usize, 1, 1);
        }
        let load_stats = ExecStats::since(array, c0, &l0);
        BfsKernel {
            layout,
            n_vertices: g.n,
            n_edges: edges.len(),
            head_row,
            ds,
            load_stats,
        }
    }

    /// Device-model cost of the load phase (paid once per graph).
    pub fn load_stats(&self) -> &ExecStats {
        &self.load_stats
    }

    /// Alias for [`BfsKernel::query`], kept for the load-and-run-once
    /// callers (CLI, figures, examples).
    pub fn run(&self, ctl: &mut Controller, src: usize) -> BfsResult {
        self.query(ctl, src)
    }

    /// Query phase: BFS from `src` (paper Fig. 11). Unlike the registry
    /// kernels' queries this **writes back into the resident rows** (the
    /// frontier fields), so it is valid once per load — reload before
    /// traversing again.
    pub fn query(&self, ctl: &mut Controller, src: usize) -> BfsResult {
        let l = &self.layout;
        ctl.begin_stats();
        // init: source vertex rows get distance 0, visited = 1
        let mut w = l.dist.pattern(0);
        w.push((l.visited, true));
        ctl.step(&Instr::Compare(l.vertex.pattern(src as u64)));
        ctl.step(&Instr::Write(w));

        let mut iterations = 0u64;
        let mut j = 0u64; // current level (line 1-2)
        let mut levels = 0u32;
        loop {
            // line 4: compare [distance == j, visited_from == 0]
            let mut pat = l.dist.pattern(j);
            pat.push((l.visited_from, false));
            ctl.step(&Instr::Compare(pat.clone()));
            ctl.step(&Instr::IfMatch);
            let got = *ctl.buffer.last().unwrap() == 1;
            if !got {
                // line 5: empty frontier — next level or terminate when
                // nothing was produced at level j+1 either
                let probe = l.dist.pattern(j + 1);
                ctl.step(&Instr::Compare(probe));
                ctl.step(&Instr::IfMatch);
                let next_exists = *ctl.buffer.last().unwrap() == 1;
                if !next_exists {
                    break;
                }
                j += 1;
                levels += 1;
                continue;
            }
            // line 6-7: first_match; mark this edge row expanded
            ctl.step(&Instr::Compare(pat));
            ctl.step(&Instr::FirstMatch);
            ctl.step(&Instr::Write(vec![(l.visited_from, true)]));
            // line 8: read (vertexID, successorID)
            ctl.step(&Instr::Read {
                base: l.vertex.base,
                width: 24,
            });
            ctl.step(&Instr::Read {
                base: l.succ.base,
                width: 24,
            });
            let bl = ctl.buffer.len();
            let vertex = ctl.buffer[bl - 2];
            let succ = ctl.buffer[bl - 1];
            debug_assert_ne!(vertex, READ_NO_MATCH);
            // lines 9-11: update all rows of the (unvisited) successor
            let mut pat = l.succ_vertex_pattern(succ);
            pat.push((l.visited, false));
            pat.push((l.valid, true));
            ctl.step(&Instr::Compare(pat));
            let mut w = l.dist.pattern(j + 1);
            w.extend(l.pred.pattern(vertex));
            w.push((l.visited, true));
            ctl.step(&Instr::Write(w));
            iterations += 1;
        }
        // readout: distance of each vertex = dist field of its head row
        let dist = self
            .head_row
            .iter()
            .map(|h| match h {
                Some(phys) => {
                    let d =
                        ctl.array
                            .fetch_row_bits(*phys, l.dist.base as usize, 16);
                    if d == DIST_INF {
                        u32::MAX
                    } else {
                        d as u32
                    }
                }
                None => u32::MAX,
            })
            .collect();
        BfsResult {
            dist,
            stats: ctl.stats(),
            iterations,
            levels,
        }
    }
}

impl BfsLayout {
    fn succ_vertex_pattern(&self, succ: u64) -> Vec<(u16, bool)> {
        self.vertex.pattern(succ)
    }
}

/// The paper's Fig. 14 cost model: vertices are examined serially at
/// `cycles_per_vertex` controller cycles each, while each examination
/// traverses that vertex's whole adjacency in parallel — TEPS = avg-degree
/// × f / c. (See EXPERIMENTS.md for the discussion of this model vs the
/// literal Algorithm 5.)
pub fn paper_model_teps(avg_degree: f64, freq_hz: f64, cycles_per_vertex: f64) -> f64 {
    avg_degree * freq_hz / cycles_per_vertex
}

/// Measured-TEPS of a literal run: traversed edges / runtime.
pub fn measured_teps(res: &BfsResult, freq_hz: f64, traversed_edges: u64) -> f64 {
    let t = res.stats.cycles as f64 / freq_hz;
    traversed_edges as f64 / t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{synth_power_law, Graph};

    fn run_bfs(g: &Graph, src: usize) -> BfsResult {
        let mut array = PrinsArray::single(g.edges(), 128);
        let mut sm = StorageManager::new(g.edges());
        let kern = BfsKernel::load(&mut sm, &mut array, g);
        let mut ctl = Controller::new(array);
        kern.run(&mut ctl, src)
    }

    #[test]
    fn bfs_on_path_graph() {
        let g = Graph {
            n: 5,
            adj: vec![vec![1], vec![2], vec![3], vec![4], vec![0]],
        };
        let res = run_bfs(&g, 0);
        assert_eq!(res.dist, vec![0, 1, 2, 3, 4]);
        assert_eq!(res.iterations, 5); // each edge expanded once
    }

    #[test]
    fn bfs_matches_cpu_reference() {
        let g = synth_power_law(300, 4.0, 2.0, 21);
        let (expect, _) = g.bfs(0);
        let res = run_bfs(&g, 0);
        assert_eq!(res.dist, expect);
    }

    #[test]
    fn bfs_diamond_records_min_distance() {
        // 0->1, 0->2, 1->3, 2->3, 3->0: vertex 3 reachable two ways
        let g = Graph {
            n: 4,
            adj: vec![vec![1, 2], vec![3], vec![3], vec![0]],
        };
        let res = run_bfs(&g, 0);
        assert_eq!(res.dist, vec![0, 1, 1, 2]);
    }

    #[test]
    fn iterations_track_edges_of_reached_vertices() {
        let g = synth_power_law(200, 5.0, 2.0, 31);
        let res = run_bfs(&g, 0);
        // every edge of every reached vertex is expanded exactly once
        assert_eq!(res.iterations as usize, g.edges());
        // cycles per iteration in the expected band (~9 + level overhead)
        let cpi = res.stats.cycles as f64 / res.iterations as f64;
        assert!((8.0..14.0).contains(&cpi), "cycles/iteration = {cpi}");
    }

    #[test]
    fn paper_model_shape() {
        // the model: speedup ordered by avg degree, ~7x for hollywood-like
        let f = 500e6;
        let t_hollywood = paper_model_teps(100.0, f, 3.0);
        let t_indochina = paper_model_teps(15.0, f, 3.0);
        assert!(t_hollywood / t_indochina > 6.0);
        assert!(t_hollywood / 2.5e9 > 6.0, "≈7x over the 2.5 GTEPS appliance");
    }
}
