//! Golden executors: the reference architecture's numeric kernels
//! (ED / DP / histogram / SpMV), AOT-compiled from python/compile/kernels/
//! golden.py and executed via PJRT. `prins validate` and the integration
//! tests use these to cross-check the associative results end-to-end.
//!
//! Artifact shapes are fixed (manifest); inputs are padded/chunked here.

use super::{lit, Runtime};
use crate::error::{bail, Result};

/// The golden-kernel executor over an opened [`Runtime`].
pub struct Golden {
    rt: Runtime,
}

impl Golden {
    /// Wrap an already-opened runtime.
    pub fn new(rt: Runtime) -> Self {
        Golden { rt }
    }

    /// Open the default artifact directory (see [`Runtime::open_default`]).
    pub fn open_default() -> Result<Self> {
        Ok(Golden::new(Runtime::open_default()?))
    }

    /// Squared Euclidean distances of samples (row-major n×d) to a center.
    pub fn euclidean(&mut self, x: &[f32], n: usize, d: usize, center: &[f32]) -> Result<Vec<f32>> {
        self.dense2d("golden_ed", x, n, d, center)
    }

    /// Dot products of vectors (row-major n×d) with a hyperplane.
    pub fn dot_product(&mut self, x: &[f32], n: usize, d: usize, h: &[f32]) -> Result<Vec<f32>> {
        self.dense2d("golden_dp", x, n, d, h)
    }

    fn dense2d(
        &mut self,
        entry: &str,
        x: &[f32],
        n: usize,
        d: usize,
        vec: &[f32],
    ) -> Result<Vec<f32>> {
        if x.len() != n * d || vec.len() != d {
            bail!("shape mismatch");
        }
        let (gn, gd) = (self.rt.manifest.golden_n, self.rt.manifest.golden_d);
        if d > gd {
            bail!("d={d} exceeds artifact dim {gd}");
        }
        // pad dims with zeros (neutral for both ED and DP), chunk rows
        let mut out = Vec::with_capacity(n);
        let mut vpad = vec.to_vec();
        vpad.resize(gd, 0.0);
        let vlit_src = vpad;
        for chunk_start in (0..n).step_by(gn) {
            let rows = (n - chunk_start).min(gn);
            let mut xpad = vec![0f32; gn * gd];
            for r in 0..rows {
                let src = &x[(chunk_start + r) * d..(chunk_start + r) * d + d];
                xpad[r * gd..r * gd + d].copy_from_slice(src);
            }
            let res = self.rt.execute(
                entry,
                &[lit::f32_2d(&xpad, gn, gd)?, lit::f32_1d(&vlit_src)],
            )?;
            let v = lit::to_f32(&res[0])?;
            out.extend_from_slice(&v[..rows]);
        }
        Ok(out)
    }

    /// 256-bin histogram on the top byte (Algorithm 3 semantics).
    pub fn histogram(&mut self, x: &[u32]) -> Result<Vec<i32>> {
        let hn = self.rt.manifest.hist_n;
        let mut total = vec![0i32; 256];
        for chunk in x.chunks(hn) {
            let mut xpad = chunk.to_vec();
            // pad with a sentinel that lands in bin 0; subtract afterwards
            let pad = hn - chunk.len();
            xpad.resize(hn, 0);
            let res = self.rt.execute("golden_hist", &[lit::u32_1d(&xpad)])?;
            let h = lit::to_i32(&res[0])?;
            for (b, v) in h.iter().enumerate() {
                total[b] += v;
            }
            total[0] -= pad as i32;
        }
        Ok(total)
    }

    /// SpMV y = A·x from COO triplets (padded to the artifact nnz).
    pub fn spmv(
        &mut self,
        rows: &[i32],
        cols: &[i32],
        vals: &[f32],
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let (gnnz, gnb) = (self.rt.manifest.spmv_nnz, self.rt.manifest.spmv_nb);
        if x.len() > gnb {
            bail!("vector length {} exceeds artifact {}", x.len(), gnb);
        }
        let mut xpad = x.to_vec();
        xpad.resize(gnb, 0.0);
        let mut y = vec![0f32; x.len()];
        let nnz = vals.len();
        for start in (0..nnz.max(1)).step_by(gnnz) {
            let end = (start + gnnz).min(nnz);
            let mut r = rows[start..end].to_vec();
            let mut c = cols[start..end].to_vec();
            let mut v = vals[start..end].to_vec();
            r.resize(gnnz, 0);
            c.resize(gnnz, 0);
            v.resize(gnnz, 0.0); // zero values: padding is neutral
            let res = self.rt.execute(
                "golden_spmv",
                &[
                    lit::i32_1d(&r),
                    lit::i32_1d(&c),
                    lit::f32_1d(&v),
                    lit::f32_1d(&xpad),
                ],
            )?;
            let part = lit::to_f32(&res[0])?;
            for i in 0..y.len() {
                y[i] += part[i];
            }
            if nnz == 0 {
                break;
            }
        }
        Ok(y)
    }
}
