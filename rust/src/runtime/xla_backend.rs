//! XLA-backed RCAM execution: run associative passes through the
//! AOT-compiled L1 Pallas kernel instead of the native bit-sliced
//! simulator.
//!
//! The backend owns a bit-plane state in the artifact's fixed shape
//! (u32[W, NW]) and executes:
//!   * `step`    — one compare+write pass (`rcam_step.hlo.txt`)
//!   * `program` — a whole microprogram via the scan-composed executor
//!     (`rcam_program.hlo.txt`), P passes per call, no host round-trips —
//!     the VMEM-residency optimization of DESIGN.md §Hardware-Adaptation.
//!
//! Integration tests assert bit-exact equality against `PrinsArray` on
//! random programs — the strongest cross-layer correctness signal in the
//! repo (rust simulator vs JAX/Pallas semantics).

use super::{lit, Runtime};
use crate::isa::{Instr, Program};
use crate::error::{bail, err, Result};

/// RCAM array state executed through the AOT-compiled Pallas kernels.
pub struct XlaRcamBackend {
    rt: Runtime,
    /// Bit planes, row-major \[W\]\[NW\] u32.
    planes: Vec<u32>,
    w: usize,
    nw: usize,
    p: usize,
}

impl XlaRcamBackend {
    /// Wrap an opened runtime; plane shape comes from its manifest.
    pub fn new(rt: Runtime) -> Self {
        let (w, nw, p) = (rt.manifest.w, rt.manifest.nw, rt.manifest.p);
        XlaRcamBackend {
            rt,
            planes: vec![0; w * nw],
            w,
            nw,
            p,
        }
    }

    /// Row count of the artifact's fixed shape.
    pub fn rows(&self) -> usize {
        self.nw * 32
    }

    /// Bit-column count of the artifact's fixed shape.
    pub fn width(&self) -> usize {
        self.w
    }

    /// Write one cell of the bit-plane state.
    pub fn set_bit(&mut self, row: usize, col: usize, v: bool) {
        assert!(row < self.rows() && col < self.w);
        let word = &mut self.planes[col * self.nw + row / 32];
        let m = 1u32 << (row % 32);
        if v {
            *word |= m;
        } else {
            *word &= !m;
        }
    }

    /// Read one cell of the bit-plane state.
    pub fn get_bit(&self, row: usize, col: usize) -> bool {
        (self.planes[col * self.nw + row / 32] >> (row % 32)) & 1 == 1
    }

    /// Write `width` bits of `value` into one row (storage path).
    pub fn load_row_bits(&mut self, row: usize, base: usize, width: usize, value: u64) {
        for i in 0..width {
            self.set_bit(row, base + i, (value >> i) & 1 == 1);
        }
    }

    /// Read `width` bits of one row (storage path).
    pub fn fetch_row_bits(&self, row: usize, base: usize, width: usize) -> u64 {
        let mut v = 0u64;
        for i in 0..width {
            if self.get_bit(row, base + i) {
                v |= 1 << i;
            }
        }
        v
    }

    fn vecs(&self, pat: &[(u16, bool)], is_mask: bool) -> Vec<u32> {
        let mut v = vec![0u32; self.w];
        for &(c, b) in pat {
            v[c as usize] = if is_mask { 1 } else { b as u32 };
        }
        v
    }

    /// One associative pass through the AOT kernel. Returns the tag words.
    pub fn step(&mut self, cpat: &[(u16, bool)], wpat: &[(u16, bool)]) -> Result<Vec<u32>> {
        let planes = lit::u32_2d(&self.planes, self.w, self.nw)?;
        let key = lit::u32_1d(&self.vecs(cpat, false));
        let cmask = lit::u32_1d(&self.cmask_vec(cpat));
        let wkey = lit::u32_1d(&self.vecs(wpat, false));
        let wmask = lit::u32_1d(&self.cmask_vec(wpat));
        let out = self
            .rt
            .execute("rcam_step", &[planes, key, cmask, wkey, wmask])?;
        if out.len() != 2 {
            bail!("rcam_step returned {} outputs", out.len());
        }
        self.planes = lit::to_u32(&out[0])?;
        lit::to_u32(&out[1])
    }

    fn cmask_vec(&self, pat: &[(u16, bool)]) -> Vec<u32> {
        let mut v = vec![0u32; self.w];
        for &(c, _) in pat {
            v[c as usize] = 1;
        }
        v
    }

    /// Run a straight-line compare/write program through the scan-composed
    /// executor, `P` passes per XLA call (no-op padding in between).
    /// Only Compare/Write/ClearColumns instructions are supported — the
    /// executor is the SIMD inner loop, not the full controller.
    pub fn run_program(&mut self, prog: &Program) -> Result<()> {
        // compile the program into (key, cmask, wkey, wmask) pass rows
        let mut passes: Vec<[Vec<u32>; 4]> = Vec::new();
        let mut i = 0;
        let instrs = &prog.instrs;
        while i < instrs.len() {
            match &instrs[i] {
                Instr::Compare(cpat) => {
                    let wpat = match instrs.get(i + 1) {
                        Some(Instr::Write(w)) => {
                            i += 1;
                            w.clone()
                        }
                        _ => vec![],
                    };
                    passes.push([
                        self.vecs(&cpat, false),
                        self.cmask_vec(&cpat),
                        self.vecs(&wpat, false),
                        self.cmask_vec(&wpat),
                    ]);
                }
                Instr::ClearColumns { base, width } => {
                    // untagged bulk clear = compare-all + write zeros
                    let wpat: Vec<(u16, bool)> =
                        (*base..base + width).map(|c| (c, false)).collect();
                    passes.push([
                        vec![0; self.w],
                        vec![0; self.w],
                        self.vecs(&wpat, false),
                        self.cmask_vec(&wpat),
                    ]);
                }
                other => bail!("unsupported instruction for XLA backend: {other:?}"),
            }
            i += 1;
        }
        // execute in chunks of P
        for chunk in passes.chunks(self.p) {
            let mut table = vec![0u32; self.p * 4 * self.w];
            for (pi, pass) in chunk.iter().enumerate() {
                for (fi, field) in pass.iter().enumerate() {
                    let off = (pi * 4 + fi) * self.w;
                    table[off..off + self.w].copy_from_slice(field);
                }
            }
            // padding rows already zero: wmask == 0 → no-op
            let planes = lit::u32_2d(&self.planes, self.w, self.nw)?;
            let passes_lit = lit::u32_3d(&table, self.p, 4, self.w)?;
            let out = self.rt.execute("rcam_program", &[planes, passes_lit])?;
            self.planes =
                lit::to_u32(out.first().ok_or_else(|| err!("no output"))?)?;
        }
        Ok(())
    }

    /// Count of rows matching a pattern (compare + popcount via the
    /// compare_count artifact).
    pub fn compare_count(&mut self, cpat: &[(u16, bool)]) -> Result<u64> {
        let planes = lit::u32_2d(&self.planes, self.w, self.nw)?;
        let key = lit::u32_1d(&self.vecs(cpat, false));
        let cmask = lit::u32_1d(&self.cmask_vec(cpat));
        let out = self.rt.execute("compare_count", &[planes, key, cmask])?;
        let v = lit::to_u32(&out[0])?;
        Ok(v[0] as u64)
    }
}
