//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts` from the JAX/Pallas compile path) and execute
//! them from the rust hot path. Python is never invoked here.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProtos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The PJRT bindings themselves live behind [`xla`], an in-tree offline
//! stub in this zero-dependency build: [`Runtime::open`] fails cleanly
//! with an "unavailable" error and every consumer takes its skip path.
//!
//! Two consumers:
//!  * [`XlaRcamBackend`] — runs the L1 Pallas associative-step kernel as an
//!    alternative execution backend for the RCAM array (bit-exact vs the
//!    native bit-sliced simulator; integration-tested).
//!  * [`Golden`] — the reference-architecture numeric kernels
//!    (ED/DP/histogram/SpMV) used by `prins validate`.

pub mod golden;
pub mod manifest;
pub mod xla;
pub mod xla_backend;

use crate::error::{err, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub use golden::Golden;
pub use manifest::Manifest;
pub use xla_backend::XlaRcamBackend;

/// A PJRT CPU client plus the compiled executables of an artifact set.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// The artifact set's parsed manifest (shapes, entry points).
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open an artifact directory (compiles nothing yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| err!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            executables: HashMap::new(),
        })
    }

    /// Default artifact directory: `$PRINS_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("PRINS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    /// Load + compile one entry point (cached across calls).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if !self.executables.contains_key(name) {
            let entry = self
                .manifest
                .entry_points
                .get(name)
                .ok_or_else(|| err!("unknown entry point {name:?}"))?;
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
            )
            .map_err(|e| err!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err!("compile {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(())
    }

    /// Execute an entry point on literals; returns the flattened tuple
    /// elements (aot.py lowers with return_tuple=True).
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(name)?;
        let exe = &self.executables[name];
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| err!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetch {name}: {e:?}"))?;
        result
            .to_tuple()
            .map_err(|e| err!("untuple {name}: {e:?}"))
    }

    /// The PJRT platform name ("cpu" on the real bindings).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Helpers converting between rust slices and XLA literals.
pub mod lit {
    use super::xla;
    use crate::error::{err, Result};

    /// 1-D u32 literal.
    pub fn u32_1d(v: &[u32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    /// rows × cols u32 literal (row-major input).
    pub fn u32_2d(v: &[u32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(v.len(), rows * cols);
        xla::Literal::vec1(v)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| err!("reshape: {e:?}"))
    }

    /// a × b × c u32 literal (row-major input).
    pub fn u32_3d(v: &[u32], a: usize, b: usize, c: usize) -> Result<xla::Literal> {
        assert_eq!(v.len(), a * b * c);
        xla::Literal::vec1(v)
            .reshape(&[a as i64, b as i64, c as i64])
            .map_err(|e| err!("reshape: {e:?}"))
    }

    /// 1-D f32 literal.
    pub fn f32_1d(v: &[f32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    /// rows × cols f32 literal (row-major input).
    pub fn f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(v.len(), rows * cols);
        xla::Literal::vec1(v)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| err!("reshape: {e:?}"))
    }

    /// 1-D i32 literal.
    pub fn i32_1d(v: &[i32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    /// Read a literal back as u32s.
    pub fn to_u32(l: &xla::Literal) -> Result<Vec<u32>> {
        l.to_vec::<u32>().map_err(|e| err!("to_vec u32: {e:?}"))
    }

    /// Read a literal back as f32s.
    pub fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
        l.to_vec::<f32>().map_err(|e| err!("to_vec f32: {e:?}"))
    }

    /// Read a literal back as i32s.
    pub fn to_i32(l: &xla::Literal) -> Result<Vec<i32>> {
        l.to_vec::<i32>().map_err(|e| err!("to_vec i32: {e:?}"))
    }
}
