//! Artifact manifest: shapes and entry-point inventory written by
//! python/compile/aot.py.
//!
//! The vendored crate set has no serde, so this module carries a minimal
//! recursive-descent JSON parser (objects, arrays, strings, numbers,
//! booleans, null — everything manifest.json uses).

use crate::error::{bail, err, Result};
use std::collections::BTreeMap;
use std::path::Path;

// ---------------------------------------------------------------------------
// mini JSON
// ---------------------------------------------------------------------------

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (f64 storage).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing characters rejected).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    /// Object member lookup (error on missing key / non-object).
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| err!("missing key {key:?}")),
            _ => bail!("not an object"),
        }
    }

    /// The value as u64 (error on non-number).
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Json::Num(n) => Ok(*n as u64),
            _ => bail!("not a number"),
        }
    }

    /// The value as a string slice (error on non-string).
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    /// The value as an array slice (error on non-array).
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    /// The value as an object map (error on non-object).
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| err!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i);
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape"),
                    }
                }
                _ => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

// ---------------------------------------------------------------------------
// manifest
// ---------------------------------------------------------------------------

/// Shape + dtype of one entry-point argument.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Dtype name as aot.py wrote it (e.g. "uint32").
    pub dtype: String,
}

/// One AOT-compiled entry point of the artifact set.
#[derive(Clone, Debug)]
pub struct EntryPoint {
    /// HLO text file name, relative to the artifact directory.
    pub file: String,
    /// Number of tuple outputs.
    pub outputs: usize,
    /// Argument specs, in call order.
    pub args: Vec<ArgSpec>,
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Bit columns of the rcam kernel's fixed shape.
    pub w: usize,
    /// u32 words per plane (rows / 32).
    pub nw: usize,
    /// Passes per scan-composed program call.
    pub p: usize,
    /// BlockSpec words per grid step.
    pub block_words: usize,
    /// Golden dense-kernel sample count.
    pub golden_n: usize,
    /// Golden dense-kernel dimensionality.
    pub golden_d: usize,
    /// Golden SpMV nonzero count.
    pub spmv_nnz: usize,
    /// Golden SpMV block count.
    pub spmv_nb: usize,
    /// Golden histogram sample count.
    pub hist_n: usize,
    /// Entry points by name.
    pub entry_points: BTreeMap<String, EntryPoint>,
}

impl Manifest {
    /// Load and parse a manifest.json file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// Parse manifest.json text.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut entry_points = BTreeMap::new();
        for (name, e) in j.get("entry_points")?.as_obj()? {
            let mut args = Vec::new();
            for a in e.get("args")?.as_arr()? {
                args.push(ArgSpec {
                    shape: a
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_u64().map(|v| v as usize))
                        .collect::<Result<_>>()?,
                    dtype: a.get("dtype")?.as_str()?.to_string(),
                });
            }
            entry_points.insert(
                name.clone(),
                EntryPoint {
                    file: e.get("file")?.as_str()?.to_string(),
                    outputs: e.get("outputs")?.as_u64()? as usize,
                    args,
                },
            );
        }
        Ok(Manifest {
            w: j.get("W")?.as_u64()? as usize,
            nw: j.get("NW")?.as_u64()? as usize,
            p: j.get("P")?.as_u64()? as usize,
            block_words: j.get("BLOCK_WORDS")?.as_u64()? as usize,
            golden_n: j.get("GOLDEN_N")?.as_u64()? as usize,
            golden_d: j.get("GOLDEN_D")?.as_u64()? as usize,
            spmv_nnz: j.get("SPMV_NNZ")?.as_u64()? as usize,
            spmv_nb: j.get("SPMV_NB")?.as_u64()? as usize,
            hist_n: j.get("HIST_N")?.as_u64()? as usize,
            entry_points,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parser_basics() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3], "b": {"c": "x\n"}, "d": true, "e": null}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\n");
        assert_eq!(j.get("d").unwrap(), &Json::Bool(true));
        assert!(Json::parse("{bogus}").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn manifest_parse() {
        let text = r#"{
            "W": 256, "NW": 2048, "P": 128, "BLOCK_WORDS": 256,
            "GOLDEN_N": 4096, "GOLDEN_D": 16, "SPMV_NNZ": 16384,
            "SPMV_NB": 1024, "HIST_N": 65536,
            "entry_points": {
                "rcam_step": {
                    "file": "rcam_step.hlo.txt", "outputs": 2,
                    "args": [{"shape": [256, 2048], "dtype": "uint32"}]
                }
            }
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.w, 256);
        assert_eq!(m.entry_points["rcam_step"].outputs, 2);
        assert_eq!(m.entry_points["rcam_step"].args[0].shape, vec![256, 2048]);
    }

    #[test]
    fn real_manifest_if_present() {
        let p = Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.entry_points.contains_key("golden_ed"));
            assert_eq!(m.nw % m.block_words, 0);
        }
    }
}
