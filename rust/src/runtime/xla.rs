//! Offline stand-in for the `xla_extension` PJRT bindings.
//!
//! The crate builds with an empty `[dependencies]` section, so the real
//! PJRT client is not linkable here. This module mirrors the small API
//! surface `runtime` uses (client, executable, HLO proto, literals) and
//! fails cleanly at runtime: [`PjRtClient::cpu`] returns an error, so
//! [`super::Runtime::open`] reports "unavailable" and every consumer
//! (`prins validate`, `prins info`, the runtime integration tests, the
//! end-to-end example) takes its documented skip path.
//!
//! To enable the real AOT artifact path, replace this module with actual
//! bindings exposing the same names — no other file changes.

const UNAVAILABLE: &str =
    "XLA/PJRT backend unavailable: this is the offline zero-dependency build \
     (src/runtime/xla.rs is a stub; link real xla_extension bindings to enable it)";

/// Error type of every stub operation. Call sites format it with `{:?}`.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(UNAVAILABLE.to_string()))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Construct the CPU client (stub: always fails with unavailable).
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable()
    }

    /// Compile a computation (stub: always fails).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }

    /// Platform name (stub: "unavailable").
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }
}

/// A compiled executable (stub: never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on arguments (stub: always fails).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// A device buffer returned by execution (stub: never constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to a host literal (stub: always fails).
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// An HLO module parsed from text (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text (stub: always fails).
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO proto.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed proto (constructible, but never executable).
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A host literal. The stub variant carries no data: every conversion
/// back out fails, and executions (the only way data would round-trip)
/// are unreachable because no client can be constructed.
pub struct Literal;

impl Literal {
    /// Build a 1-D literal (stub: carries no data).
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    /// Reshape (stub: always fails).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    /// Read back as a host vector (stub: always fails).
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }

    /// Flatten a tuple literal (stub: always fails).
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }
}
