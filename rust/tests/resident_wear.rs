//! Wear/ledger regression suite for resident datasets: after the load
//! phase, Q queries must not add load-phase wear (an accidental reload
//! would re-write every stored field — a per-row wear spike this suite
//! would catch), and query-only cycles must match the kernels' analytic
//! query floors exactly.

use prins::algorithms::{
    DotKernel, EuclideanKernel, HistogramKernel, ReduceEngine, SpmvKernel,
};
use prins::controller::Controller;
use prins::rcam::PrinsArray;
use prins::storage::wear::wear_report;
use prins::storage::StorageManager;
use prins::workloads::{synth_csr, synth_hist_samples, synth_samples, synth_uniform, Rng};

const Q: usize = 4;

#[test]
fn histogram_queries_leave_wear_untouched_and_hit_floor() {
    let xs = synth_hist_samples(1500, 3);
    let mut array = PrinsArray::single(xs.len(), 40);
    array.enable_wear_tracking();
    let mut sm = StorageManager::new(xs.len());
    let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
    // load wear: one sample write + one valid-bit write per row
    let w_load = wear_report(&array).unwrap();
    assert_eq!(w_load.total_writes, 2 * xs.len() as u64);
    assert_eq!(w_load.max_writes, 2);
    let mut ctl = Controller::new(array);
    let floor = kern.query_floor_cycles(&ctl.array);
    for q in 0..Q {
        let res = kern.query_at(&mut ctl, [24u16, 16, 8, 0][q]);
        assert_eq!(res.stats.cycles, floor, "query {q} off the analytic floor");
        assert_eq!(res.stats.ledger.n_write, 0, "query {q} wrote");
    }
    // compare-only queries: wear is bit-for-bit what the load left
    assert_eq!(wear_report(&ctl.array).unwrap(), w_load);
}

#[test]
fn ed_queries_add_constant_query_wear_only() {
    let (n, dims, k) = (24usize, 2usize, 2usize);
    let x = synth_samples(n, dims, 4, 7);
    let centers = synth_uniform(k * dims, 8);
    let layout = prins::algorithms::euclidean::EuclideanLayout::new(dims);
    let mut array = PrinsArray::single(n, layout.width as usize);
    array.enable_wear_tracking();
    let mut sm = StorageManager::new(n);
    let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
    let w_load = wear_report(&array).unwrap().total_writes;
    assert_eq!(w_load, (n * dims) as u64, "load wear: one write per attribute");
    let mut ctl = Controller::new(array);
    let floor = kern.query_floor_cycles(k);
    // Queries write broadcast/scratch fields, so wear grows — but by the
    // same per-query delta every time (query #1 may differ slightly: it
    // runs on pristine scratch). A reload would add n×dims load writes
    // per query on top of the steady delta; that spike is what we gate.
    let mut deltas = Vec::new();
    let mut prev = w_load;
    for q in 0..Q {
        let res = kern.query(&mut ctl, &sm, &centers, k);
        assert_eq!(res.stats.cycles, floor, "query {q} off the analytic floor");
        let now = wear_report(&ctl.array).unwrap().total_writes;
        deltas.push(now - prev);
        prev = now;
    }
    // steady state from query #2 on: identical input state → identical
    // tag trace → identical wear delta
    for (q, w) in deltas.windows(2).enumerate().skip(1) {
        assert_eq!(w[0], w[1], "query {}: wear delta drifted (reload?)", q + 1);
    }
    // no query's delta contains the load-phase writes
    for (q, &d) in deltas.iter().enumerate() {
        assert!(
            d < deltas[Q - 1] + (n * dims) as u64,
            "query {q}: wear delta {d} looks like a reload"
        );
    }
}

#[test]
fn dp_queries_hit_floor_with_identical_ledgers() {
    let (n, dims) = (32usize, 3usize);
    let x = synth_samples(n, dims, 4, 9);
    let h = synth_uniform(dims, 10);
    let layout = prins::algorithms::dot::DotLayout::new(dims);
    let mut array = PrinsArray::single(n, layout.width as usize);
    array.enable_wear_tracking();
    let mut sm = StorageManager::new(n);
    let kern = DotKernel::load(&mut sm, &mut array, &x, n, dims);
    let w_load = wear_report(&array).unwrap().total_writes;
    assert_eq!(w_load, (n * dims) as u64);
    let mut ctl = Controller::new(array);
    let floor = kern.query_floor_cycles();
    let first = kern.query(&mut ctl, &sm, &h);
    assert_eq!(first.stats.cycles, floor);
    let w1 = wear_report(&ctl.array).unwrap().total_writes;
    assert!(w1 > w_load, "queries do write scratch fields");
    // steady state from query #2 on: identical ledgers and wear deltas
    let steady = kern.query(&mut ctl, &sm, &h);
    assert_eq!(steady.stats.cycles, floor);
    let w2 = wear_report(&ctl.array).unwrap().total_writes;
    for q in 2..Q {
        let res = kern.query(&mut ctl, &sm, &h);
        assert_eq!(res.stats.cycles, floor, "query {q}");
        assert_eq!(res.stats.ledger, steady.stats.ledger, "query {q}: ledger drifted");
    }
    let w_end = wear_report(&ctl.array).unwrap().total_writes;
    assert_eq!(
        w_end - w2,
        (Q as u64 - 2) * (w2 - w1),
        "constant per-query wear after steady state"
    );
}

#[test]
fn spmv_queries_hit_floor_and_never_rewrite_the_matrix() {
    let a = synth_csr(40, 280, 11);
    let mut rng = Rng::seed_from(12);
    let x: Vec<f32> = (0..a.n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let mut array = PrinsArray::single(a.nnz(), 256);
    array.enable_wear_tracking();
    let mut sm = StorageManager::new(a.nnz());
    let kern = SpmvKernel::load(&mut sm, &mut array, &a);
    let w_load = wear_report(&array).unwrap();
    assert_eq!(w_load.total_writes, 4 * a.nnz() as u64);
    assert_eq!(w_load.max_writes, 4, "rowid, colid, sign, magnitude per row");
    let mut ctl = Controller::new(array);
    let floor = kern.query_floor_cycles();
    let first = kern.query(&mut ctl, &x, ReduceEngine::ChainTree);
    assert_eq!(first.stats.cycles, floor);
    let w1 = wear_report(&ctl.array).unwrap().total_writes;
    // steady state from query #2 on (query #1 ran on pristine scratch)
    let steady = kern.query(&mut ctl, &x, ReduceEngine::ChainTree);
    assert_eq!(steady.stats.cycles, floor);
    let w2 = wear_report(&ctl.array).unwrap().total_writes;
    for q in 2..Q {
        let res = kern.query(&mut ctl, &x, ReduceEngine::ChainTree);
        assert_eq!(res.stats.cycles, floor, "query {q}");
        assert_eq!(res.stats.ledger, steady.stats.ledger, "query {q}: ledger drifted");
        assert!(
            res.y.iter().zip(&first.y).all(|(p, s)| p.to_bits() == s.to_bits()),
            "query {q}: results drifted"
        );
    }
    let w_end = wear_report(&ctl.array).unwrap().total_writes;
    assert_eq!(w_end - w2, (Q as u64 - 2) * (w2 - w1), "constant per-query wear");
    assert!(w1 > w_load.total_writes, "queries do write work fields");
}

/// Field-by-field [`prins::host::rack::RackStats`] equality (the struct
/// carries f64 energies, so it has no `PartialEq`): shared-read replies
/// must be *bit*-identical to the exclusive path, energies included.
fn assert_rack_stats_eq(a: &prins::host::rack::RackStats, b: &prins::host::rack::RackStats) {
    assert_eq!(a.shards, b.shards);
    assert_eq!(a.max_shard_cycles, b.max_shard_cycles);
    assert_eq!(a.link_messages, b.link_messages);
    assert_eq!(a.link_bytes, b.link_bytes);
    assert_eq!(a.link_cycles, b.link_cycles);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.device_energy_j.to_bits(), b.device_energy_j.to_bits());
    assert_eq!(a.link_energy_j.to_bits(), b.link_energy_j.to_bits());
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
    assert_eq!(a.shard_stats.len(), b.shard_stats.len());
    for (sa, sb) in a.shard_stats.iter().zip(&b.shard_stats) {
        assert_eq!(sa.cycles, sb.cycles);
        assert_eq!(sa.instructions, sb.instructions);
        assert_eq!(sa.passes, sb.passes);
        assert_eq!(sa.ledger, sb.ledger);
    }
}

/// The shared-read regression gate (DESIGN.md §Serving): the write-free
/// concurrent-reader path must not mutate wear or ledger state. Eight
/// readers hammer one resident dataset through `query_args_shared`
/// (`&self` — exactly what the server's worker pool calls) while the
/// wear score and every reply stay bit-identical to the serial
/// exclusive-path reference.
#[test]
fn shared_readers_add_zero_wear_and_match_the_exclusive_path() {
    use prins::algorithms::kernel::find_verb;
    use prins::host::rack::PrinsRack;

    let rack = PrinsRack::new(1);
    for (verb, n, args) in [("HIST", 1500usize, vec![]), ("SEARCH", 400, vec!["100", "5000"])] {
        let entry = find_verb(verb).unwrap();
        let mut res = (entry.synth_load)(&rack, n, 4, 3);
        assert!(res.shared_readable(), "{verb}: write-free kernel on ideal rack");
        // serial anchors: load wear is the per-row value+valid writes
        // (max 2 per row — same anchor the serial suite pins above),
        // and one exclusive query is the reply reference
        assert_eq!(res.wear_score(), Some(2), "{verb}: load wear anchor");
        let reference = res.query_args(&args).unwrap();
        assert!(reference.fidelity.is_none(), "{verb}: ideal rack");
        assert_eq!(res.wear_score(), Some(2), "{verb}: exclusive query wore the array");

        // 8 concurrent readers × 16 queries each over the same rows
        let res_ref = &res;
        let (reference_ref, args_ref) = (&reference, &args);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(move || {
                    for _ in 0..16 {
                        let out = res_ref.query_args_shared(args_ref).unwrap();
                        assert_eq!(out.fields, reference_ref.fields, "{verb}: reply drifted");
                        assert!(out.fidelity.is_none());
                        assert_rack_stats_eq(&out.rack, &reference_ref.rack);
                    }
                });
            }
        });

        // per-query wear delta under concurrency: exactly zero, like the
        // serial anchor — and the exclusive path still reproduces the
        // reference afterwards (no hidden state was touched)
        assert_eq!(res.wear_score(), Some(2), "{verb}: shared readers wore the array");
        let after = res.query_args(&args).unwrap();
        assert_eq!(after.fields, reference.fields);
        assert_rack_stats_eq(&after.rack, &reference.rack);
    }
}
