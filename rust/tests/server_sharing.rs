//! Cross-session sharing battery (docs/PROTOCOL.md §Sharing): the
//! resident table is server-wide, so datasets loaded on one connection
//! are queryable from every other. This suite pins the three guarantees
//! that make that safe: shared reads from any number of connections are
//! bit-equal to a lone serial session and leave no trace (frozen wear,
//! unchanged epoch); reads from *other* connections refresh eviction
//! recency; and the FIFO admission gate never starves a shared reader
//! behind an exclusive query stream. A fourth test makes the
//! cross-connection coalescer observable through the `STATS` counters
//! while holding the bit-equality line.

use prins::host::server::Server;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn ask(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(conn, "{req}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim().to_string()
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

fn ask_serially(addr: std::net::SocketAddr, script: &[&str]) -> Vec<String> {
    let (mut conn, mut reader) = connect(addr);
    script.iter().map(|req| ask(&mut conn, &mut reader, req)).collect()
}

fn ask_pipelined(addr: std::net::SocketAddr, script: &[&str]) -> Vec<String> {
    let (mut conn, mut reader) = connect(addr);
    let burst: String = script.iter().map(|r| format!("{r}\n")).collect();
    conn.write_all(burst.as_bytes()).unwrap();
    let mut replies = Vec::with_capacity(script.len());
    let mut line = String::new();
    for req in script {
        line.clear();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "connection dropped at {req:?}"
        );
        replies.push(line.trim().to_string());
    }
    replies
}

fn stat_field(reply: &str, key: &str) -> u64 {
    reply
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix(key))
        .unwrap_or_else(|| panic!("no {key} in {reply}"))
        .parse()
        .unwrap()
}

#[test]
fn shared_reads_across_connections_are_bit_equal_and_leave_no_trace() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let setup = ask_serially(
        server.addr,
        &["LOAD SEARCH 400 9", "LOAD HIST 300 5", "QUIT"],
    );
    assert!(setup[0].starts_with("OK id=1 kind=search"), "{}", setup[0]);
    assert!(setup[1].starts_with("OK id=2 kind=hist"), "{}", setup[1]);

    // mixed shared reads over both datasets, including the listing —
    // every field of every reply is pinned by the lone reference run
    let mut script = Vec::new();
    for _ in 0..4 {
        script.extend_from_slice(&[
            "SEARCH 1 100 5000",
            "HIST 2",
            "SEARCH 1 7 7",
            "SEARCH 1 100 5000",
        ]);
    }
    script.push("DATASETS");
    script.push("QUIT");
    let reference = ask_serially(server.addr, &script);
    assert_eq!(
        reference[script.len() - 2],
        "OK count=2 epoch=2 ds=1:search:400:1 ds=2:hist:300:1"
    );

    const CLIENTS: usize = 8;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    std::thread::scope(|s| {
        for _ in 0..CLIENTS {
            let (reference, barrier, script) = (&reference, barrier.clone(), &script);
            s.spawn(move || {
                barrier.wait();
                let got = ask_pipelined(server.addr, script);
                assert_eq!(&got, reference, "shared reads diverged under concurrency");
            });
        }
    });

    // no trace: wear is frozen under shared reads, the epoch did not
    // move, so a post-storm lone run repeats the reference bit for bit
    let after = ask_serially(server.addr, &script);
    assert_eq!(after, reference, "the storm left state behind");
    server.shutdown();
}

#[test]
fn reads_from_another_connection_keep_a_dataset_hot_against_eviction() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let (mut a, mut ra) = connect(server.addr);
    // connection A fills the table: ids 1..=16, identical wear
    for i in 0..16 {
        let r = ask(&mut a, &mut ra, "LOAD HIST 32 1");
        assert!(r.starts_with(&format!("OK id={}", i + 1)), "{r}");
    }
    // connection B reads id 1 — recency must be stamped through the
    // shared table, not per-session bookkeeping
    let (mut b, mut rb) = connect(server.addr);
    let q = ask(&mut b, &mut rb, "HIST 1");
    assert!(q.contains("dataset=1"), "{q}");

    // A's next load evicts wear-aware LRU: id 1 was refreshed by B, so
    // the victim is id 2 — were sessions still isolated, A would evict
    // the dataset B just read
    let r = ask(&mut a, &mut ra, "LOAD HIST 32 1");
    assert!(r.ends_with("evicted=2"), "{r}");
    let ds = ask(&mut a, &mut ra, "DATASETS");
    assert!(ds.contains("ds=1:"), "B's read did not keep id 1 hot: {ds}");
    // and B still sees its dataset alive
    let q2 = ask(&mut b, &mut rb, "HIST 1");
    assert_eq!(q2, q, "survivor dataset drifted across the eviction");
    server.shutdown();
}

#[test]
fn shared_reader_is_not_starved_by_exclusive_query_streams() {
    // regression for FIFO admission: two connections stream exclusive
    // SPMV queries back to back while a third issues serial shared
    // reads. The ticket gate admits the reader in arrival order, so
    // every read must complete well inside the socket timeout — a
    // writer-preference or exclusive-streak gate would starve it.
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let setup = ask_serially(server.addr, &["LOAD SPMV 40 280 5", "LOAD HIST 300 5", "QUIT"]);
    assert!(setup[0].starts_with("OK id=1"), "{}", setup[0]);
    assert!(setup[1].starts_with("OK id=2"), "{}", setup[1]);

    let exclusive_script: Vec<&str> = std::iter::repeat("SPMV 1 9")
        .take(150)
        .chain(["QUIT"])
        .collect();
    let barrier = Arc::new(Barrier::new(3));
    std::thread::scope(|s| {
        for _ in 0..2 {
            let (barrier, script) = (barrier.clone(), &exclusive_script);
            s.spawn(move || {
                barrier.wait();
                let replies = ask_pipelined(server.addr, script);
                assert_eq!(replies.len(), script.len());
            });
        }
        let barrier = barrier.clone();
        s.spawn(move || {
            barrier.wait();
            let (mut conn, mut reader) = connect(server.addr);
            conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let first = ask(&mut conn, &mut reader, "HIST 2");
            assert!(first.contains("dataset=2"), "{first}");
            for _ in 0..24 {
                // a starved reader times out the socket and panics here
                let r = ask(&mut conn, &mut reader, "HIST 2");
                assert_eq!(r, first, "shared read drifted under exclusive load");
            }
            assert_eq!(ask(&mut conn, &mut reader, "QUIT"), "BYE");
        });
    });
    server.shutdown();
}

#[test]
fn coalesced_search_bursts_stay_bit_equal_and_show_in_stats() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let setup = ask_serially(server.addr, &["LOAD SEARCH 400 9", "QUIT"]);
    assert!(setup[0].starts_with("OK id=1"), "{}", setup[0]);

    // lone-reference reply for the probe query: search is wear-free, so
    // this is the pinned answer for every later burst member
    let reference = ask_serially(server.addr, &["SEARCH 1 100 5000"])[0].clone();
    assert!(reference.contains("dataset=1"), "{reference}");

    // fire one-packet bursts until the mux provably merged one: packet
    // arrival isn't guaranteed to land in a single sweep, so retry — the
    // replies must be bit-equal to the lone reference on every attempt,
    // coalesced or not
    let script: Vec<&str> = std::iter::repeat("SEARCH 1 100 5000").take(8).collect();
    let mut merged = false;
    for _ in 0..20 {
        let barrier = Arc::new(Barrier::new(2));
        std::thread::scope(|s| {
            for _ in 0..2 {
                let (barrier, script, reference) = (barrier.clone(), &script, &reference);
                s.spawn(move || {
                    barrier.wait();
                    for got in ask_pipelined(server.addr, script) {
                        assert_eq!(&got, reference, "coalesced reply diverged");
                    }
                });
            }
        });
        let stats = ask_serially(server.addr, &["STATS 1"])[0].clone();
        if stat_field(&stats, "coal_batches=") >= 1 {
            assert!(stat_field(&stats, "coal_members=") >= 2, "{stats}");
            assert!(stat_field(&stats, "coal_cycles=") >= 1, "{stats}");
            merged = true;
            break;
        }
    }
    assert!(merged, "no burst was ever coalesced across 20 attempts");
    server.shutdown();
}
