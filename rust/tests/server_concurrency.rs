//! Multi-client server concurrency suite over the shared namespace: N
//! client threads drive one `Server` with interleaved `RACK` / `LOAD` /
//! query / `DROP` verbs. Dataset ids are **globally monotonic** (the
//! resident table is server-wide, docs/PROTOCOL.md §Sharing), so
//! clients parse the ids their `LOAD`s return and scripts reference
//! them through placeholders; replies are then compared **modulo those
//! ids** — every other byte must match a serial single-client
//! reference run. Shard counts (`RACK`) stay per-connection.

use prins::host::server::Server;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Run a request script on one fresh connection. `{0}`, `{1}`, … in a
/// request line expand to the ids returned by the script's `LOAD`s so
/// far (in order). Returns the replies plus the parsed ids.
fn run_script(addr: std::net::SocketAddr, script: &[String]) -> (Vec<String>, Vec<u64>) {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut replies = Vec::with_capacity(script.len());
    let mut ids: Vec<u64> = Vec::new();
    for req in script {
        let mut req = req.clone();
        for (i, id) in ids.iter().enumerate() {
            req = req.replace(&format!("{{{i}}}"), &id.to_string());
        }
        writeln!(conn, "{req}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let reply = line.trim().to_string();
        if req.starts_with("LOAD ") {
            let id = reply
                .split_whitespace()
                .find_map(|kv| kv.strip_prefix("id="))
                .unwrap_or_else(|| panic!("LOAD did not return an id: {reply}"))
                .parse::<u64>()
                .unwrap();
            ids.push(id);
        }
        replies.push(reply);
    }
    (replies, ids)
}

/// Rewrite the id-bearing tokens of one reply (`id=`, `dataset=`,
/// `dropped=`, and the trailing id of `ERR unknown dataset N`) to
/// placeholder tags, so runs that drew different global ids compare
/// byte-for-byte everywhere else.
fn normalize(reply: &str, ids: &[u64]) -> String {
    let toks: Vec<&str> = reply.split_whitespace().collect();
    let mut out: Vec<String> = Vec::with_capacity(toks.len());
    for (pos, tok) in toks.iter().enumerate() {
        let mut mapped = (*tok).to_string();
        for (i, id) in ids.iter().enumerate() {
            for key in ["id=", "dataset=", "dropped="] {
                if mapped == format!("{key}{id}") {
                    mapped = format!("{key}#{i}");
                }
            }
            // "ERR unknown dataset N"
            if pos > 0 && toks[pos - 1] == "dataset" && mapped == id.to_string() {
                mapped = format!("#{i}");
            }
        }
        out.push(mapped);
    }
    out.join(" ")
}

fn normalized(replies: &[String], ids: &[u64]) -> Vec<String> {
    replies.iter().map(|r| normalize(r, ids)).collect()
}

/// Per-client script: client i gets its own shard count, workload sizes
/// and seeds, so cross-talk between concurrent workloads cannot
/// reproduce the reference replies. Every loaded dataset is dropped at
/// the end so concurrent passes never trip table eviction.
fn script_for(i: usize) -> Vec<String> {
    let shards = 1 + (i % 3); // 1, 2, 3, 1, ...
    let n = 300 + 40 * i;
    let seed = 7 + i as u64;
    vec![
        "PING".to_string(),
        format!("RACK {shards}"),
        format!("LOAD HIST {n} {seed}"),
        format!("LOAD DP 24 4 {seed}"),
        "HIST {0}".to_string(),
        "HIST {0}".to_string(), // repeat: resident query must be stable
        format!("DP {{1}} {}", seed + 1),
        format!("HIST {n} {seed}"), // one-shot interleaved with resident
        "DROP {0}".to_string(),
        "HIST {0}".to_string(), // dropped: ERR, but the session stays usable
        format!("DP {{1}} {}", seed + 1),
        "DROP {1}".to_string(),
        "QUIT".to_string(),
    ]
}

#[test]
fn concurrent_clients_stay_bit_equal_to_serial_runs_modulo_global_ids() {
    const CLIENTS: usize = 4;
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr;

    // reference pass: each script alone, sequentially, same server
    let expected: Vec<Vec<String>> = (0..CLIENTS)
        .map(|i| {
            let (replies, ids) = run_script(addr, &script_for(i));
            normalized(&replies, &ids)
        })
        .collect();
    // sanity on the reference itself
    for (i, replies) in expected.iter().enumerate() {
        assert_eq!(replies[0], "PONG");
        assert!(replies[2].starts_with("OK id=#0 kind=hist"), "client {i}: {}", replies[2]);
        assert!(replies[3].starts_with("OK id=#1 kind=dp"), "client {i}: {}", replies[3]);
        assert_eq!(replies[4], replies[5], "client {i}: resident repeat drifted");
        assert_eq!(replies[8], "OK dropped=#0", "client {i}: {}", replies[8]);
        assert_eq!(replies[9], "ERR unknown dataset #0", "client {i}: {}", replies[9]);
        assert_eq!(replies[6], replies[10], "client {i}: DP after DROP drifted");
        assert_eq!(*replies.last().unwrap(), "BYE");
    }

    // concurrent pass: all clients at once against the same server; the
    // global ids differ, everything else must not
    let got: Vec<Vec<String>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|i| {
                s.spawn(move || {
                    let (replies, ids) = run_script(addr, &script_for(i));
                    normalized(&replies, &ids)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(g, e, "client {i}: concurrent replies diverge from single-client run");
    }
    server.shutdown();
}

#[test]
fn interleaved_queries_on_one_shared_server_stay_deterministic() {
    // Two rounds of the same mixed workload from many threads: every
    // normalized reply for a given request line must be identical across
    // rounds and across threads — concurrent loads shift the global ids,
    // nothing else.
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr;
    let script: Vec<String> = vec![
        "LOAD SPMV 40 280 5".into(),
        "SPMV {0} 9".into(),
        "SPMV {0} 9".into(),
        "LOAD ED 32 2 6".into(),
        "ED {1} 3 11".into(),
        "SPMV {0} 9".into(),
        "DROP {0}".into(),
        "DROP {1}".into(),
        "QUIT".into(),
    ];
    let rounds: Vec<Vec<Vec<String>>> = (0..2)
        .map(|_| {
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..3)
                    .map(|_| {
                        s.spawn(|| {
                            let (replies, ids) = run_script(addr, &script);
                            normalized(&replies, &ids)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        })
        .collect();
    let reference = &rounds[0][0];
    assert!(reference[1].contains("checksum=") && reference[1].contains("dataset=#0"));
    assert_eq!(reference[1], reference[2], "resident SPMV repeat drifted");
    assert_eq!(reference[1], reference[5], "resident SPMV drifted after another LOAD");
    for (r, round) in rounds.iter().enumerate() {
        for (t, replies) in round.iter().enumerate() {
            assert_eq!(replies, reference, "round {r} thread {t} diverged");
        }
    }
    server.shutdown();
}
