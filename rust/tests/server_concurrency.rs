//! Multi-client server concurrency suite: N client threads drive one
//! `Server` with interleaved `RACK` / `LOAD` / query / `DROP` verbs.
//! Sessions must be fully isolated — per-connection dataset ids, shard
//! counts, and resident data — and every reply must be bit-equal to the
//! same script executed alone on a single connection.

use prins::host::server::Server;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Run a request script on one fresh connection, collecting the replies.
fn run_script(addr: std::net::SocketAddr, script: &[String]) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut replies = Vec::with_capacity(script.len());
    for req in script {
        writeln!(conn, "{req}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        replies.push(line.trim().to_string());
    }
    replies
}

/// Per-client script: client i gets its own shard count, workload sizes
/// and seeds, so concurrent sessions that leak state into each other
/// cannot produce the reference replies.
fn script_for(i: usize) -> Vec<String> {
    let shards = 1 + (i % 3); // 1, 2, 3, 1, ...
    let n = 300 + 40 * i;
    let seed = 7 + i as u64;
    vec![
        "PING".to_string(),
        format!("RACK {shards}"),
        format!("LOAD HIST {n} {seed}"),
        format!("LOAD DP 24 4 {seed}"),
        "DATASETS".to_string(),
        "HIST 1".to_string(),
        "HIST 1".to_string(), // repeat: resident query must be stable
        format!("DP 2 {}", seed + 1),
        format!("HIST {n} {seed}"), // one-shot interleaved with resident
        "DROP 1".to_string(),
        "DATASETS".to_string(),
        "HIST 1".to_string(), // dropped: ERR, but session stays usable
        format!("DP 2 {}", seed + 1),
        "QUIT".to_string(),
    ]
}

#[test]
fn concurrent_sessions_are_isolated_and_bit_equal_to_single_client() {
    const CLIENTS: usize = 4;
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr;

    // reference pass: each script alone, sequentially
    let expected: Vec<Vec<String>> = (0..CLIENTS)
        .map(|i| run_script(addr, &script_for(i)))
        .collect();
    // sanity on the reference itself
    for (i, replies) in expected.iter().enumerate() {
        assert_eq!(replies[0], "PONG");
        assert!(replies[2].starts_with("OK id=1 kind=hist"), "client {i}: {}", replies[2]);
        assert!(replies[3].starts_with("OK id=2 kind=dp"), "client {i}: {}", replies[3]);
        assert!(replies[4].starts_with("OK count=2"), "client {i}: {}", replies[4]);
        assert_eq!(replies[5], replies[6], "client {i}: resident repeat drifted");
        assert!(replies[11].starts_with("ERR"), "client {i}: {}", replies[11]);
        assert_eq!(replies[7], replies[12], "client {i}: DP after DROP drifted");
        assert_eq!(*replies.last().unwrap(), "BYE");
    }

    // concurrent pass: all clients at once against the same server
    let got: Vec<Vec<String>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|i| s.spawn(move || run_script(addr, &script_for(i))))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(g, e, "client {i}: concurrent replies diverge from single-client run");
    }
    server.shutdown();
}

#[test]
fn interleaved_queries_on_one_shared_server_stay_deterministic() {
    // Two rounds of the same mixed workload from many threads: every
    // reply for a given request line must be identical across rounds and
    // across threads (the server holds no cross-connection state).
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr;
    let script: Vec<String> = vec![
        "LOAD SPMV 40 280 5".into(),
        "SPMV 1 9".into(),
        "SPMV 1 9".into(),
        "LOAD ED 32 2 6".into(),
        "ED 2 3 11".into(),
        "SPMV 1 9".into(),
        "QUIT".into(),
    ];
    let rounds: Vec<Vec<Vec<String>>> = (0..2)
        .map(|_| {
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..3)
                    .map(|_| s.spawn(|| run_script(addr, &script)))
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        })
        .collect();
    let reference = &rounds[0][0];
    assert!(reference[1].contains("checksum=") && reference[1].contains("dataset=1"));
    assert_eq!(reference[1], reference[2], "resident SPMV repeat drifted");
    assert_eq!(reference[1], reference[5], "resident SPMV drifted after another LOAD");
    for (r, round) in rounds.iter().enumerate() {
        for (t, replies) in round.iter().enumerate() {
            assert_eq!(replies, reference, "round {r} thread {t} diverged");
        }
    }
    server.shutdown();
}
