//! Registry-wide static verification gate (`prins verify` as a test).
//!
//! Proves, without executing a single query instruction, that every
//! registered kernel's synthesized microprograms satisfy the analyzer's
//! rule set over the seeded shape grid — and that the analyzer itself
//! catches deliberately-broken fixtures. Also hosts the satellite
//! gates: the registry usage/arity round-trip and the random-program
//! structural property tests.

use prins::algorithms::kernel::{registry, ResidentDyn};
use prins::analysis::contract::write_freedom_overlay;
use prins::analysis::{
    check_program, verify_registry, ArrayShape, QueryPlan, RuleId, Severity,
};
use prins::controller::Controller;
use prins::host::rack::PrinsRack;
use prins::isa::{Instr, Program};
use prins::rcam::PrinsArray;
use prins::workloads::{random_program, Rng};
use std::collections::HashSet;

/// Load `entry` on a 1-shard rack with a small seeded dataset.
fn small_resident(entry: &prins::algorithms::kernel::KernelEntry) -> Box<dyn ResidentDyn> {
    let rack = PrinsRack::new(1);
    (entry.synth_load)(&rack, 24, 2, 7)
}

// ---------------------------------------------------------------- tentpole

#[test]
fn every_registered_kernel_verifies_clean_over_the_shape_grid() {
    let reports = verify_registry();
    let names: HashSet<&str> = reports.iter().map(|r| r.kernel).collect();
    assert_eq!(
        names,
        ["hist", "dp", "ed", "spmv", "search"].into_iter().collect(),
        "registry drifted: update this gate alongside REGISTRY"
    );
    for r in &reports {
        assert!(r.shapes > 0 && r.checked_programs > 0 && r.checked_instructions > 0);
        assert!(
            r.is_clean(),
            "{}: {} diagnostic(s): {:?}",
            r.kernel,
            r.diagnostics.len(),
            r.diagnostics
                .iter()
                .map(|(c, d)| format!("[{c}] {d}"))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn c01_write_freedom_is_a_structural_proof_for_claiming_kernels() {
    // beyond the driver's C01 pass: inspect the synthesized instruction
    // stream directly — a write-free query plan contains literally zero
    // Write/ClearColumns instructions
    let claiming: Vec<_> = registry().iter().filter(|e| e.write_free_queries).collect();
    assert!(
        claiming.iter().map(|e| e.name).collect::<HashSet<_>>()
            == ["hist", "search"].into_iter().collect(),
        "write_free_queries set drifted: update this gate"
    );
    for entry in claiming {
        let res = small_resident(entry);
        for q in 0..4 {
            for pq in res.query_plans_seeded(q, 7) {
                for prog in &pq.plan.programs {
                    for instr in &prog.instrs {
                        assert!(
                            !matches!(instr, Instr::Write(_) | Instr::ClearColumns { .. }),
                            "{}: {instr:?} in a write-free query",
                            entry.name
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn c03_overlay_kernels_confine_query_writes_to_scratch_columns() {
    // the scratch-overlay shared-read path is sound only if overlay
    // kernels never write a stored column; beyond the driver's C03 pass,
    // inspect the synthesized streams directly
    let claiming: Vec<_> = registry().iter().filter(|e| e.overlay_queries).collect();
    assert!(
        claiming.iter().map(|e| e.name).collect::<HashSet<_>>()
            == ["hist", "dp", "ed", "search"].into_iter().collect(),
        "overlay_queries set drifted: update this gate"
    );
    for entry in claiming {
        let res = small_resident(entry);
        for q in 0..4 {
            for pq in res.query_plans_seeded(q, 7) {
                assert!(
                    write_freedom_overlay(&pq.plan, &pq.resident_columns).is_empty(),
                    "{}: overlay query plan writes stored columns",
                    entry.name
                );
                for prog in &pq.plan.programs {
                    for instr in &prog.instrs {
                        match instr {
                            Instr::Write(p) => assert!(
                                p.iter().all(|(c, _)| !pq.resident_columns.contains(c)),
                                "{}: write {instr:?} touches resident {:?}",
                                entry.name,
                                pq.resident_columns
                            ),
                            Instr::ClearColumns { base, width } => assert!(
                                *base >= pq.resident_columns.end
                                    || base.saturating_add(*width)
                                        <= pq.resident_columns.start,
                                "{}: {instr:?} overlaps resident {:?}",
                                entry.name,
                                pq.resident_columns
                            ),
                            _ => {}
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn c03_fixture_writing_a_stored_column_is_rejected() {
    // a deliberately-broken overlay plan: one write hitting stored col 2
    // and a clear straddling the resident/scratch boundary
    let mut p = Program::new();
    p.push(Instr::Compare(vec![(0, true)]));
    p.push(Instr::Write(vec![(8, true), (2, false)]));
    p.push(Instr::ClearColumns { base: 7, width: 2 });
    let plan = QueryPlan {
        programs: vec![p],
        extra_cycles: 0,
    };
    let diags = write_freedom_overlay(&plan, &(0..8));
    assert_eq!(diags.len(), 2, "{diags:?}");
    assert!(diags.iter().all(|d| d.rule == RuleId::C03));
    assert!(diags.iter().all(|d| d.severity == Severity::Error));
    assert_eq!(diags[0].index, Some(1));
    assert_eq!(diags[1].index, Some(2));
    // the same plan confined to scratch columns is clean
    assert!(write_freedom_overlay(&plan, &(20..28)).is_empty());
}

#[test]
fn c02_every_plan_estimate_equals_the_kernel_floor() {
    for entry in registry() {
        for &shards in &[1usize, 2] {
            let rack = PrinsRack::new(shards);
            let res = (entry.synth_load)(&rack, 48, 3, 11);
            for q in 0..4 {
                let plans = res.query_plans_seeded(q, 11);
                assert_eq!(plans.len(), shards);
                for (s, pq) in plans.iter().enumerate() {
                    assert_eq!(
                        pq.plan.cycle_estimate(),
                        pq.floor_cycles,
                        "{} shard {s}/{shards} q={q}: plan estimate != analytic floor",
                        entry.name
                    );
                }
                // the dyn-level floor is the slowest shard's floor — the
                // plans must reproduce it exactly
                let max_floor = plans.iter().map(|p| p.floor_cycles).max().unwrap();
                assert_eq!(max_floor, res.query_floor_seeded(q, 11), "{}", entry.name);
            }
        }
    }
}

// ------------------------------------------------------- broken fixtures

/// A fixture that violates W01 (column 99 on a 16-wide array), W02
/// (contradictory bits on column 3), and T01 twice (a shift that
/// flushes the whole 32-row chain, then a write under the resulting
/// statically-empty tags).
fn broken_fixture() -> Program {
    let mut p = Program::new();
    p.push(Instr::Compare(vec![(99, true)]));
    p.push(Instr::Compare(vec![(3, true), (3, false)]));
    p.push(Instr::SetTagsAll);
    p.push(Instr::ShiftTagsUp(32));
    p.push(Instr::Write(vec![(0, true)]));
    p
}

#[test]
fn broken_fixture_trips_w01_w02_and_t01() {
    let shape = ArrayShape {
        rows: 32,
        rows_per_module: 16,
        width: 16,
    };
    let diags = check_program(&broken_fixture(), &shape);
    let fired: HashSet<RuleId> = diags.iter().map(|d| d.rule).collect();
    assert!(
        fired.is_superset(&[RuleId::W01, RuleId::W02, RuleId::T01].into_iter().collect()),
        "fired: {fired:?}, diags: {diags:?}"
    );
    // the findings are anchored and all errors here
    assert!(diags.iter().all(|d| d.severity == Severity::Error));
    assert!(diags.iter().all(|d| d.index.is_some()));
    // W01 points at the out-of-bounds compare, T01 at the flush and the
    // dead write
    assert!(diags.iter().any(|d| d.rule == RuleId::W01 && d.index == Some(0)));
    assert!(diags.iter().any(|d| d.rule == RuleId::W02 && d.index == Some(1)));
    assert!(diags.iter().any(|d| d.rule == RuleId::T01 && d.index == Some(3)));
    assert!(diags.iter().any(|d| d.rule == RuleId::T01 && d.index == Some(4)));
}

#[test]
fn execute_checked_rejects_broken_and_accepts_clean_programs() {
    let mut ctl = Controller::new(PrinsArray::new(2, 16, 16));
    let err = ctl.execute_checked(&broken_fixture()).unwrap_err();
    assert!(format!("{err:#}").contains("rejected by static analysis"));
    assert_eq!(ctl.array.cycles, 0, "rejected program must not execute");

    let mut clean = Program::new();
    clean.push(Instr::SetTagsAll);
    clean.push(Instr::Compare(vec![(0, true), (1, false)]));
    clean.push(Instr::ReduceCount);
    let out = ctl.execute_checked(&clean).unwrap().to_vec();
    assert_eq!(out.len(), 1);
    assert!(ctl.array.cycles > 0);
}

// ------------------------------------------- satellite: registry round-trip

#[test]
fn registry_usage_strings_round_trip_their_own_arities() {
    for entry in registry() {
        // grammar lines carry exactly the advertised arity:
        //   query_usage    = VERB id <arity args>
        //   one_shot_usage = VERB <arity args>
        //   load_usage     = LOAD <VERB> ...
        let q_tokens: Vec<&str> = entry.query_usage.split_whitespace().collect();
        assert_eq!(q_tokens.len(), entry.query_arity + 2, "{}", entry.query_usage);
        assert_eq!(q_tokens[0], entry.verb);
        assert_eq!(q_tokens[1], "id");
        let o_tokens: Vec<&str> = entry.one_shot_usage.split_whitespace().collect();
        assert_eq!(o_tokens.len(), entry.one_shot_arity + 1, "{}", entry.one_shot_usage);
        assert_eq!(o_tokens[0], entry.verb);
        assert!(
            entry.load_usage.starts_with(&format!("LOAD {} ", entry.verb)),
            "{}",
            entry.load_usage
        );

        // and the advertised query arity round-trips through the
        // kernel's own parser: exactly-arity numeric args parse and run,
        // any other count is rejected before parsing
        let mut res = small_resident(entry);
        let args: Vec<String> = (1..=entry.query_arity).map(|i| i.to_string()).collect();
        let arg_refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
        res.query_args(&arg_refs)
            .unwrap_or_else(|e| panic!("{}: arity-{} args rejected: {e:#}",
                entry.name, entry.query_arity));
        let mut extra = args.clone();
        extra.push("1".into());
        let extra_refs: Vec<&str> = extra.iter().map(|s| s.as_str()).collect();
        assert!(
            res.query_args(&extra_refs).is_err(),
            "{}: arity {} accepted {} args",
            entry.name,
            entry.query_arity,
            extra.len()
        );
    }
}

// --------------------------------------- satellite: random-program property

#[test]
fn random_programs_span_partition_and_cycle_accounting_hold() {
    let shape = ArrayShape {
        rows: 64,
        rows_per_module: 16,
        width: 32,
    };
    for seed in 0..32u64 {
        let mut rng = Rng::seed_from(seed);
        let len = 1 + (seed as usize % 64);
        let p = random_program(&mut rng, shape.width as u16, 8, len);
        assert_eq!(p.len(), len);

        // spans() exactly partitions the instruction stream…
        let spans: Vec<_> = p.spans().collect();
        let flat: Vec<Instr> = spans
            .iter()
            .flat_map(|s| s.instrs.iter().cloned())
            .collect();
        assert_eq!(flat, p.instrs, "seed {seed}: spans lose or reorder instrs");
        for (i, s) in spans.iter().enumerate() {
            assert!(!s.instrs.is_empty(), "seed {seed}: empty span");
            // …into maximal uniform runs: every instr agrees with its
            // span's class, and adjacent spans alternate
            assert!(s.instrs.iter().all(|x| x.is_data_parallel() == s.data_parallel));
            if i > 0 {
                assert_ne!(spans[i - 1].data_parallel, s.data_parallel);
            }
        }

        // …and the program estimate is exactly the sum over spans
        let span_cycles: u64 = spans
            .iter()
            .map(|s| s.instrs.iter().map(|x| x.cycles()).sum::<u64>())
            .sum();
        assert_eq!(p.cycle_estimate(), span_cycles, "seed {seed}");

        // well-formed-by-construction: the analyzer proves it clean
        // (max_shift 8 stays below rows_per_module and rows)
        let diags = check_program(&p, &shape);
        assert!(diags.is_empty(), "seed {seed}: {diags:?}");
    }
}
