//! Property suite for the sharded rack (ISSUE 3 acceptance gate): for
//! random workloads and shard counts {1, 2, 3, 8}, the rack-sharded
//! histogram / dot-product / Euclidean-distance / SpMV paths must produce
//! results, checksums, and merged histograms **bit-equal** to the
//! single-device kernels. Cycles and energy may legitimately differ (the
//! rack charges the host link and one controller per shard) and are
//! asserted ≥ the single-device analytic floors:
//!
//!   * ED / DP: per-shard cycles are row-count-independent, so the
//!     slowest shard equals the single device exactly and the rack total
//!     (plus link) strictly exceeds it;
//!   * histogram: every shard replays the identical 2-op-per-bin
//!     program; the link latency (≥ 1000 cycles/message) strictly
//!     dominates the per-shard reduction-drain savings (≤ ~20 cycles);
//!   * SpMV: the O(n) broadcast and multiply phases are shard-invariant
//!     floors; link latency dominates the chain-reduce level savings;
//!   * energy: row-partitioning preserves the dominant write/compare
//!     event counts, and per-shard controller static power plus link
//!     energy only add — so rack energy exceeds the single device's
//!     dynamic energy.

use prins::algorithms::{
    dot_sharded, euclidean_sharded, histogram_sharded, spmv_sharded, DotKernel, EuclideanKernel,
    HistogramKernel, ReduceEngine, SpmvKernel,
};
use prins::controller::Controller;
use prins::host::rack::PrinsRack;
use prins::rcam::shard::local_topk;
use prins::rcam::{DeviceModel, ExecBackend, InterconnectModel, PrinsArray};
use prins::storage::StorageManager;
use prins::workloads::{synth_csr, synth_hist_samples, Rng};

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 8];

fn rack(shards: usize) -> PrinsRack {
    PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        ExecBackend::Serial,
        InterconnectModel::default(),
    )
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} ({x} vs {y})");
    }
}

#[test]
fn prop_sharded_equals_single_histogram() {
    let mut rng = Rng::seed_from(0x5EED_0001);
    let dev = DeviceModel::default();
    for case in 0..4u64 {
        let n = 200 + rng.below(2500) as usize;
        let xs = synth_hist_samples(n, 90 + case);
        let mut array = PrinsArray::single(n, 40);
        let mut sm = StorageManager::new(n);
        let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
        let mut ctl = Controller::new(array);
        let single = kern.run(&mut ctl);
        for s in SHARD_COUNTS {
            let res = histogram_sharded(&rack(s), &xs);
            let label = format!("hist case {case} shards {s}");
            assert_eq!(res.hist, single.hist, "{label}: merged histogram");
            assert_eq!(res.rack.shards, s, "{label}");
            assert_eq!(res.rack.link_messages, 2 * s as u64, "{label}");
            assert!(
                res.rack.max_shard_cycles >= 2 * 256,
                "{label}: per-shard issue-cycle floor"
            );
            assert!(
                res.rack.total_cycles >= single.stats.cycles,
                "{label}: rack {} < single {}",
                res.rack.total_cycles,
                single.stats.cycles
            );
            assert!(
                res.rack.energy_j > single.stats.ledger.dynamic_energy_j(&dev),
                "{label}: energy floor"
            );
        }
    }
}

#[test]
fn prop_sharded_equals_single_dot() {
    let mut rng = Rng::seed_from(0x5EED_0002);
    let dev = DeviceModel::default();
    for case in 0..3 {
        let n = 16 + rng.below(60) as usize;
        let dims = 1 + rng.below(4) as usize;
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let h: Vec<f32> = (0..dims).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let layout = prins::algorithms::dot::DotLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = DotKernel::load(&mut sm, &mut array, &x, n, dims);
        let mut ctl = Controller::new(array);
        let single = kern.run(&mut ctl, &sm, &h);
        let single_checksum: f32 = single.dp.iter().sum();
        for s in SHARD_COUNTS {
            let res = dot_sharded(&rack(s), &x, n, dims, &h);
            let label = format!("dp case {case} shards {s}");
            assert_bits_eq(&res.dp, &single.dp, &label);
            assert_eq!(
                res.checksum.to_bits(),
                single_checksum.to_bits(),
                "{label}: checksum"
            );
            // the DP program is row-count independent: every shard replays
            // it exactly, so the slowest shard IS the single device
            assert_eq!(
                res.rack.max_shard_cycles, single.stats.cycles,
                "{label}: shard cycles"
            );
            assert!(
                res.rack.total_cycles > single.stats.cycles,
                "{label}: link charge must be visible"
            );
            assert!(
                res.rack.energy_j > single.stats.ledger.dynamic_energy_j(&dev),
                "{label}: energy floor"
            );
        }
    }
}

#[test]
fn prop_sharded_equals_single_euclidean() {
    let mut rng = Rng::seed_from(0x5EED_0003);
    let dev = DeviceModel::default();
    for case in 0..2 {
        let n = 16 + rng.below(48) as usize;
        let dims = 1 + rng.below(3) as usize;
        let k = 1 + rng.below(3) as usize;
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let centers: Vec<f32> = (0..k * dims).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let layout = prins::algorithms::euclidean::EuclideanLayout::new(dims);
        let mut array = PrinsArray::single(n, layout.width as usize);
        let mut sm = StorageManager::new(n);
        let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
        let mut ctl = Controller::new(array);
        let single = kern.run(&mut ctl, &sm, &centers, k);
        let single_checksum: f32 = single.dists.iter().flat_map(|d| d.iter()).sum();
        for s in SHARD_COUNTS {
            let res = euclidean_sharded(&rack(s), &x, n, dims, &centers, k, 3);
            let label = format!("ed case {case} shards {s}");
            for c in 0..k {
                assert_bits_eq(&res.dists[c], &single.dists[c], &format!("{label} center {c}"));
                // the k-way top-k merge must agree with a global sort of
                // the single-device distances
                let expect = local_topk(&single.dists[c], 0, 3);
                assert_eq!(res.nearest[c], expect, "{label} center {c}: top-k merge");
            }
            assert_eq!(
                res.checksum.to_bits(),
                single_checksum.to_bits(),
                "{label}: checksum"
            );
            assert_eq!(
                res.rack.max_shard_cycles, single.stats.cycles,
                "{label}: shard cycles"
            );
            assert!(res.rack.total_cycles > single.stats.cycles, "{label}");
            assert!(
                res.rack.energy_j > single.stats.ledger.dynamic_energy_j(&dev),
                "{label}: energy floor"
            );
        }
    }
}

#[test]
fn prop_sharded_equals_single_spmv() {
    let mut rng = Rng::seed_from(0x5EED_0004);
    let dev = DeviceModel::default();
    for case in 0..2u64 {
        let n = 48 + rng.below(200) as usize;
        let nnz = n * (2 + rng.below(6) as usize);
        let a = synth_csr(n, nnz, 40 + case);
        let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let mut array = PrinsArray::single(a.nnz(), 256);
        let mut sm = StorageManager::new(a.nnz());
        let kern = SpmvKernel::load(&mut sm, &mut array, &a);
        let mut ctl = Controller::new(array);
        let single = kern.run(&mut ctl, &x, ReduceEngine::ChainTree);
        let single_checksum: f32 = single.y.iter().sum();
        for s in SHARD_COUNTS {
            let res = spmv_sharded(&rack(s), &a, &x);
            let label = format!("spmv case {case} shards {s}");
            assert_bits_eq(&res.y, &single.y, &label);
            assert_eq!(
                res.checksum.to_bits(),
                single_checksum.to_bits(),
                "{label}: checksum"
            );
            // broadcast (O(n), serialized over x) and multiply (row-count
            // independent) are shard-invariant analytic floors
            assert!(
                res.rack.max_shard_cycles
                    >= single.broadcast_cycles + single.multiply_cycles,
                "{label}: broadcast+multiply floor"
            );
            assert!(
                res.rack.total_cycles >= single.stats.cycles,
                "{label}: rack {} < single {} (link must dominate reduce savings)",
                res.rack.total_cycles,
                single.stats.cycles
            );
            assert!(
                res.rack.energy_j > single.stats.ledger.dynamic_energy_j(&dev),
                "{label}: energy floor"
            );
        }
    }
}
