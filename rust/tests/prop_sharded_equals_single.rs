//! Property suite for the sharded rack, registry-driven (ISSUE 3
//! acceptance gate, re-based on the ISSUE 5 kernel framework): for
//! **every kernel in the registry** — hist, dp, ed, spmv, search, and
//! whatever is registered next, with zero per-kernel test code — the
//! rack-sharded path at shard counts {2, 3, 8} must produce merged
//! results **bit-equal** (canonical `ShardMerge::bits` encoding: every
//! f32 via `to_bits`, every count verbatim — for ED that includes the
//! k-way top-k merge) to the 1-shard rack, which computes exactly the
//! single-device values. Cycles and energy may legitimately differ and
//! are bounded instead:
//!
//!   * cycles: per-shard programs are row-count-independent (ed/dp,
//!     search and hist bar the reduction-tree drain) or floored by the
//!     shard-invariant broadcast+multiply phases (spmv), while the link
//!     charge (≥ 1000 cycles/message, 2 messages per shard) strictly
//!     dominates any per-shard savings — so the sharded total must be
//!     ≥ the single device's kernel cycles;
//!   * energy: row-partitioning preserves the dominant write/compare
//!     event counts, and per-shard controller static power plus link
//!     energy only add — so rack energy exceeds the single device's
//!     dynamic energy.

use prins::algorithms::registry;
use prins::host::rack::PrinsRack;
use prins::rcam::{DeviceModel, ExecBackend, InterconnectModel};

const SHARD_COUNTS: [usize; 3] = [2, 3, 8];

fn rack(shards: usize) -> PrinsRack {
    PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        ExecBackend::Serial,
        InterconnectModel::default(),
    )
}

#[test]
fn prop_sharded_equals_single_for_every_registered_kernel() {
    let dev = DeviceModel::default();
    // (rows, dims, seed) cases: enough rows that every shard of an
    // 8-way split is non-empty and weighted CSR cuts actually differ
    let cases = [(220usize, 3usize, 90u64), (73, 2, 91)];
    for entry in registry() {
        for (case, &(n, dims, seed)) in cases.iter().enumerate() {
            let mut single = (entry.synth_load)(&rack(1), n, dims, seed);
            let s_out = single.query_seeded(0, seed);
            assert_eq!(s_out.rack.shards, 1);
            let single_kernel_cycles = s_out.rack.max_shard_cycles;
            let single_dynamic_j: f64 = s_out
                .rack
                .shard_stats
                .iter()
                .map(|st| st.ledger.dynamic_energy_j(&dev))
                .sum();
            // independent analytic anchor: the 1-shard reference itself
            // must sit exactly on the kernel's query floor, so a cycle
            // inflation in the shared framework path cannot hide by
            // affecting every shard count identically
            assert_eq!(
                single_kernel_cycles,
                single.query_floor_seeded(0, seed),
                "{}: single-device cycles off the analytic floor",
                entry.name
            );
            for s in SHARD_COUNTS {
                let mut res = (entry.synth_load)(&rack(s), n, dims, seed);
                let out = res.query_seeded(0, seed);
                let label = format!("{} case {case} shards {s}", entry.name);
                assert_eq!(out.bits, s_out.bits, "{label}: merged result bits");
                assert_eq!(out.fields, s_out.fields, "{label}: reply fields");
                assert_eq!(out.rack.shards, s, "{label}");
                assert_eq!(out.rack.link_messages, 2 * s as u64, "{label}");
                // exact slowest-shard pin at every shard count
                assert_eq!(
                    out.rack.max_shard_cycles,
                    res.query_floor_seeded(0, seed),
                    "{label}: shard cycles off the analytic floor"
                );
                assert!(
                    out.rack.total_cycles >= single_kernel_cycles,
                    "{label}: rack {} < single {} (link must dominate per-shard savings)",
                    out.rack.total_cycles,
                    single_kernel_cycles
                );
                assert!(
                    out.rack.total_cycles > out.rack.max_shard_cycles,
                    "{label}: link charge must be visible"
                );
                assert!(
                    out.rack.energy_j > single_dynamic_j,
                    "{label}: energy floor"
                );
            }
        }
    }
}

#[test]
fn sharded_load_report_charges_every_shard_and_the_link() {
    for entry in registry() {
        for s in [1usize, 4] {
            let res = (entry.synth_load)(&rack(s), 96, 2, 7);
            let load = res.load_report();
            let label = format!("{} shards {s}", entry.name);
            assert_eq!(load.shards, s, "{label}");
            assert_eq!(load.link_messages, s as u64, "{label}: one load message per shard");
            assert!(load.link_bytes > 0, "{label}: dataset payload charged");
            assert!(load.total_cycles > load.max_shard_cycles, "{label}");
            let writes: u64 = load.shard_stats.iter().map(|st| st.ledger.n_write).sum();
            assert!(writes > 0, "{label}: load phase must write rows");
            assert_eq!(
                writes,
                res.expected_load_writes(),
                "{label}: load wrote off the per-field floor"
            );
        }
    }
}
