//! Protocol conformance suite driven by `docs/PROTOCOL.md`: every
//! malformed or out-of-range request line must yield a single `ERR`
//! reply on a live connection — never a panic, never a silent
//! disconnect — and the connection (including its session state) must
//! remain fully usable afterwards.

use prins::host::server::Server;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Every line here is specified (or implied) invalid by docs/PROTOCOL.md.
const MALFORMED: &[&str] = &[
    // unknown verbs / framing
    "BOGUS",
    "BOGUS 1 2",
    "",               // empty line
    "rack 2",         // verbs are case-sensitive upper-case
    "ping",
    "PING extra",     // wrong arity for PING
    // RACK bounds
    "RACK 0",
    "RACK 65",
    "RACK -1",
    "RACK two",
    "RACK 999999999999999999999999", // u64 overflow -> parse error
    // one-shot kernel bounds
    "HIST 0 1",
    "HIST 1048577 1",          // n > 2^20
    "HIST 99999999999999999999 1",
    "DP 0 4 1",
    "DP 10 0 1",
    "DP 10 17 1",              // dims > 16
    "DP 65537 4 1",            // n > 2^16
    "ED 0 2 1 1",
    "ED 10 9 1 1",             // dims > 8
    "ED 10 2 0 1",
    "ED 10 2 17 1",            // k > 16
    "SPMV 0 10 1",
    "SPMV 16385 10 1",         // n > 2^14
    "SPMV 64 262145 1",        // nnz > 2^18
    "SPMV 64 0 1",
    // LOAD grammar and bounds
    "LOAD",
    "LOAD FOO 10 1",
    "LOAD hist 10 1",          // kinds are upper-case
    "LOAD HIST",
    "LOAD HIST 10",
    "LOAD HIST 0 1",
    "LOAD HIST 1048577 1",
    "LOAD DP 10 1",            // missing dims
    "LOAD DP 10 0 1",
    "LOAD DP 10 17 1",
    "LOAD ED 10 9 1",
    "LOAD SPMV 0 10 1",
    "LOAD SPMV 64 262145 1",
    // registry misuse: ids that don't exist, malformed ids
    "DROP",
    "DROP 7",
    "DROP x",
    "HIST 99",                 // dataset-id form, unknown id
    "DP 99 1",
    "ED 99 1 1",
    "SPMV 99 1",
    "DATASETS 1",              // wrong arity
    // FAULTS grammar and bounds
    "FAULTS 0.5",              // missing seed
    "FAULTS 1 2 3 4",          // too many args
    "FAULTS 1.5 1",            // BER >= 1
    "FAULTS -0.1 1",           // negative BER
    "FAULTS nan 1",            // non-finite BER
    "FAULTS x 1",              // unparseable BER
    "FAULTS 0.01 x",           // unparseable seed
    "FAULTS 0.01 1 x",         // unparseable stuck count
    "FAULTS off",              // keywords are upper-case
];

#[test]
fn every_malformed_line_errs_and_leaves_the_connection_alive() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    for req in MALFORMED {
        line.clear();
        writeln!(conn, "{req}").unwrap();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "{req:?}: server disconnected instead of replying");
        assert!(
            line.starts_with("ERR"),
            "{req:?}: expected ERR, got {line:?}"
        );
        // the connection and its session must remain usable
        line.clear();
        writeln!(conn, "PING").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG", "{req:?}: connection unusable afterwards");
    }
    server.shutdown();
}

#[test]
fn errors_do_not_corrupt_session_state() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
        line.clear();
        writeln!(conn, "{req}").unwrap();
        reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    };
    // establish state, then fire errors through it
    assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
    assert!(ask(&mut conn, &mut reader, "LOAD HIST 400 7").starts_with("OK id=1"));
    assert!(ask(&mut conn, &mut reader, "RACK 0").starts_with("ERR"));
    assert!(ask(&mut conn, &mut reader, "LOAD FOO 1 2").starts_with("ERR"));
    assert!(ask(&mut conn, &mut reader, "DROP 9").starts_with("ERR"));
    // shard count and the resident dataset survived every error
    assert_eq!(ask(&mut conn, &mut reader, "RACK"), "OK shards=2");
    assert_eq!(
        ask(&mut conn, &mut reader, "DATASETS"),
        "OK count=1 ds=1:hist:400:2"
    );
    let q = ask(&mut conn, &mut reader, "HIST 1");
    assert!(q.contains("total=400") && q.contains("dataset=1"), "{q}");
    server.shutdown();
}

#[test]
fn dataset_limit_is_enforced_and_recoverable() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
        line.clear();
        writeln!(conn, "{req}").unwrap();
        reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    };
    // fill the registry to its documented cap of 16
    for i in 0..16 {
        let r = ask(&mut conn, &mut reader, "LOAD HIST 16 1");
        assert!(r.starts_with(&format!("OK id={}", i + 1)), "{r}");
    }
    let full = ask(&mut conn, &mut reader, "LOAD HIST 16 1");
    assert!(full.starts_with("ERR") && full.contains("limit"), "{full}");
    // the error is actionable: it names the DROP verb and lists every
    // resident id the client could free
    assert!(full.contains("DROP"), "{full}");
    for id in 1..=16 {
        assert!(full.contains(&id.to_string()), "id {id} missing from {full}");
    }
    // dropping one frees a slot; ids keep monotonically increasing
    assert_eq!(ask(&mut conn, &mut reader, "DROP 3"), "OK dropped=3");
    assert!(ask(&mut conn, &mut reader, "LOAD HIST 16 1").starts_with("OK id=17"));
    server.shutdown();
}
