//! Protocol conformance suite driven by `docs/PROTOCOL.md`: every
//! malformed or out-of-range request line must yield a single `ERR`
//! reply on a live connection — never a panic, never a silent
//! disconnect — and the connection (including its session state) must
//! remain fully usable afterwards.

use prins::host::server::Server;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Every line here is specified (or implied) invalid by docs/PROTOCOL.md.
const MALFORMED: &[&str] = &[
    // unknown verbs / framing
    "BOGUS",
    "BOGUS 1 2",
    "",               // empty line
    "rack 2",         // verbs are case-sensitive upper-case
    "ping",
    "PING extra",     // wrong arity for PING
    // RACK bounds
    "RACK 0",
    "RACK 65",
    "RACK -1",
    "RACK two",
    "RACK 999999999999999999999999", // u64 overflow -> parse error
    // one-shot kernel bounds
    "HIST 0 1",
    "HIST 1048577 1",          // n > 2^20
    "HIST 99999999999999999999 1",
    "DP 0 4 1",
    "DP 10 0 1",
    "DP 10 17 1",              // dims > 16
    "DP 65537 4 1",            // n > 2^16
    "ED 0 2 1 1",
    "ED 10 9 1 1",             // dims > 8
    "ED 10 2 0 1",
    "ED 10 2 17 1",            // k > 16
    "SPMV 0 10 1",
    "SPMV 16385 10 1",         // n > 2^14
    "SPMV 64 262145 1",        // nnz > 2^18
    "SPMV 64 0 1",
    // LOAD grammar and bounds
    "LOAD",
    "LOAD FOO 10 1",
    "LOAD hist 10 1",          // kinds are upper-case
    "LOAD HIST",
    "LOAD HIST 10",
    "LOAD HIST 0 1",
    "LOAD HIST 1048577 1",
    "LOAD DP 10 1",            // missing dims
    "LOAD DP 10 0 1",
    "LOAD DP 10 17 1",
    "LOAD ED 10 9 1",
    "LOAD SPMV 0 10 1",
    "LOAD SPMV 64 262145 1",
    // registry misuse: ids that don't exist, malformed ids
    "DROP",
    "DROP 7",
    "DROP x",
    "HIST 99",                 // dataset-id form, unknown id
    "DP 99 1",
    "ED 99 1 1",
    "SPMV 99 1",
    "DATASETS 1",              // wrong arity
    // FAULTS grammar and bounds
    "FAULTS 0.5",              // missing seed
    "FAULTS 1 2 3 4",          // too many args
    "FAULTS 1.5 1",            // BER >= 1
    "FAULTS -0.1 1",           // negative BER
    "FAULTS nan 1",            // non-finite BER
    "FAULTS x 1",              // unparseable BER
    "FAULTS 0.01 x",           // unparseable seed
    "FAULTS 0.01 1 x",         // unparseable stuck count
    "FAULTS off",              // keywords are upper-case
];

#[test]
fn every_malformed_line_errs_and_leaves_the_connection_alive() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    for req in MALFORMED {
        line.clear();
        writeln!(conn, "{req}").unwrap();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "{req:?}: server disconnected instead of replying");
        assert!(
            line.starts_with("ERR"),
            "{req:?}: expected ERR, got {line:?}"
        );
        // the connection and its session must remain usable
        line.clear();
        writeln!(conn, "PING").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG", "{req:?}: connection unusable afterwards");
    }
    server.shutdown();
}

#[test]
fn errors_do_not_corrupt_session_state() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
        line.clear();
        writeln!(conn, "{req}").unwrap();
        reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    };
    // establish state, then fire errors through it
    assert_eq!(ask(&mut conn, &mut reader, "RACK 2"), "OK shards=2");
    assert!(ask(&mut conn, &mut reader, "LOAD HIST 400 7").starts_with("OK id=1"));
    assert!(ask(&mut conn, &mut reader, "RACK 0").starts_with("ERR"));
    assert!(ask(&mut conn, &mut reader, "LOAD FOO 1 2").starts_with("ERR"));
    assert!(ask(&mut conn, &mut reader, "DROP 9").starts_with("ERR"));
    // shard count and the resident dataset survived every error
    assert_eq!(ask(&mut conn, &mut reader, "RACK"), "OK shards=2");
    assert_eq!(
        ask(&mut conn, &mut reader, "DATASETS"),
        "OK count=1 epoch=1 ds=1:hist:400:2"
    );
    let q = ask(&mut conn, &mut reader, "HIST 1");
    assert!(q.contains("total=400") && q.contains("dataset=1"), "{q}");
    server.shutdown();
}

#[test]
fn dataset_cap_evicts_instead_of_erroring() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    let mut ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
        line.clear();
        writeln!(conn, "{req}").unwrap();
        reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    };
    // fill the registry to its documented capacity of 16; no load below
    // the cap may report an eviction
    for i in 0..16 {
        let r = ask(&mut conn, &mut reader, "LOAD HIST 16 1");
        assert!(r.starts_with(&format!("OK id={}", i + 1)), "{r}");
        assert!(!r.contains("evicted="), "premature eviction: {r}");
    }
    // the 17th load succeeds and names its victim in the pinned
    // `evicted=` reply key: id 1 is least-recently-used at equal wear
    let full = ask(&mut conn, &mut reader, "LOAD HIST 16 1");
    assert!(full.starts_with("OK id=17"), "{full}");
    assert!(full.ends_with("evicted=1"), "{full}");
    let ds = ask(&mut conn, &mut reader, "DATASETS");
    assert!(ds.starts_with("OK count=16"), "{ds}");
    assert!(!ds.contains("ds=1:"), "evicted id listed: {ds}");
    // a malformed LOAD must never cost a resident dataset
    assert!(ask(&mut conn, &mut reader, "LOAD HIST x 1").starts_with("ERR"));
    assert!(ask(&mut conn, &mut reader, "DATASETS").starts_with("OK count=16"));
    // DROP still works and ids keep monotonically increasing; a load
    // into the freed slot is below the cap, so nothing is evicted
    assert_eq!(ask(&mut conn, &mut reader, "DROP 3"), "OK dropped=3");
    let r = ask(&mut conn, &mut reader, "LOAD HIST 16 1");
    assert!(r.starts_with("OK id=18"), "{r}");
    assert!(!r.contains("evicted="), "{r}");
    server.shutdown();
}

/// Deterministic framing fuzz (satellite of DESIGN.md §Serving): the
/// multiplexer's line framer must tolerate arbitrarily split and
/// coalesced byte chunks — partial lines, multi-line bursts, and
/// interleaved malformed verbs — replying exactly once per line, `ERR`
/// per bad line, with session state intact afterwards.
#[test]
fn framing_survives_random_chunking_and_interleaved_garbage() {
    use prins::workloads::Rng;
    for seed in [11u64, 29, 83] {
        // fresh server per seed: the resident table is server-wide, so a
        // reused server would carry ids and epoch across seeds
        let server = Server::spawn("127.0.0.1:0").unwrap();
        let mut rng = Rng::seed_from(seed);
        let mut conn = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());

        // script: a resident load, then a random interleave of valid
        // shared reads, valid exclusive verbs, and malformed lines
        let mut script: Vec<&str> = vec!["LOAD HIST 32 1"];
        let mut expect: Vec<&str> = vec!["OK id=1"];
        for _ in 0..120 {
            let (req, rep) = match rng.below(4) {
                0 => ("PING", "PONG"),
                1 => ("HIST 1", "OK "),
                2 => ("RACK", "OK shards=1"),
                _ => (MALFORMED[rng.below(MALFORMED.len() as u64) as usize], "ERR"),
            };
            script.push(req);
            expect.push(rep);
        }
        let wire: String = script.iter().map(|l| format!("{l}\n")).collect();

        // feed the exact same bytes in random chunks: sizes 1..=48 so
        // single lines are split mid-token and bursts span many lines
        let bytes = wire.as_bytes();
        let mut at = 0;
        while at < bytes.len() {
            let n = (1 + rng.below(48) as usize).min(bytes.len() - at);
            conn.write_all(&bytes[at..at + n]).unwrap();
            conn.flush().unwrap();
            at += n;
            if rng.below(4) == 0 {
                std::thread::yield_now(); // let the mux drain mid-line
            }
        }

        // exactly one reply per line, in order, with the right shape
        let mut line = String::new();
        for (i, (req, want)) in script.iter().zip(&expect).enumerate() {
            line.clear();
            let n = reader.read_line(&mut line).unwrap();
            assert!(n > 0, "seed {seed}: dropped at line {i} ({req:?})");
            assert!(
                line.starts_with(want),
                "seed {seed}: line {i} ({req:?}) expected {want:?} prefix, got {line:?}"
            );
        }
        // the session survived the storm: state checks, then goodbye
        line.clear();
        writeln!(conn, "DATASETS").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "OK count=1 epoch=1 ds=1:hist:32:1", "seed {seed}");
        line.clear();
        writeln!(conn, "QUIT").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "BYE", "seed {seed}");
        server.shutdown();
    }
}
