//! Compiled-program cache correctness gates (DESIGN.md §Batching &
//! program cache): serving a query from a cached [`QueryPlan`] must be
//! observationally identical to synthesizing the plan fresh — same
//! result bits, same reply fields, same charged cycles — across
//! simulator worker counts and shard layouts, and invalidation must
//! force re-synthesis without changing any result.

use prins::algorithms::kernel::registry;
use prins::host::rack::PrinsRack;
use prins::rcam::{DeviceModel, ExecBackend, InterconnectModel};

const ROWS: usize = 96;
const DENSE_CAP: usize = 48;
const DIMS: usize = 3;
const SEED: u64 = 11;

fn rack(shards: usize, workers: usize) -> PrinsRack {
    PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        ExecBackend::from_workers(workers),
        InterconnectModel::default(),
    )
}

/// Rows for one registry entry: dense (microcoded) kernels cap like the
/// bench sweeps so the matrix of configurations stays fast.
fn rows_for(dense: bool) -> usize {
    if dense {
        ROWS.min(DENSE_CAP)
    } else {
        ROWS
    }
}

#[test]
fn cached_queries_are_bit_identical_to_fresh_synthesis() {
    for &workers in &[1usize, 4] {
        for &shards in &[1usize, 2, 8] {
            let rack = rack(shards, workers);
            for entry in registry() {
                let nrows = rows_for(entry.dense);
                let mut res = (entry.synth_load)(&rack, nrows, DIMS, SEED);
                // q=0 twice: the first run synthesizes (or not, for
                // kernels without cache keys), the repeat serves any
                // cached plans — every observable must agree exactly
                let cold = res.query_seeded(0, SEED);
                let warm = res.query_seeded(0, SEED);
                let ctx = format!("{} workers={workers} shards={shards}", entry.name);
                assert_eq!(cold.bits, warm.bits, "{ctx}: result bits drifted");
                assert_eq!(cold.fields, warm.fields, "{ctx}: reply fields drifted");
                assert_eq!(
                    cold.rack.total_cycles, warm.rack.total_cycles,
                    "{ctx}: cycle ledger drifted"
                );
                assert_eq!(
                    cold.rack.max_shard_cycles, warm.rack.max_shard_cycles,
                    "{ctx}: shard critical path drifted"
                );
                assert_eq!(
                    cold.rack.link_bytes, warm.rack.link_bytes,
                    "{ctx}: link traffic drifted"
                );
                // a fresh load answering the same parameters — all
                // synthesis, no cache — must also agree
                let mut fresh = (entry.synth_load)(&rack, nrows, DIMS, SEED);
                let f = fresh.query_seeded(0, SEED);
                assert_eq!(cold.bits, f.bits, "{ctx}: cached vs fresh-load bits");
                assert_eq!(cold.fields, f.fields, "{ctx}: cached vs fresh-load fields");
                assert_eq!(
                    cold.rack.total_cycles, f.rack.total_cycles,
                    "{ctx}: cached vs fresh-load cycles"
                );
            }
        }
    }
}

#[test]
fn cache_counters_account_for_repeats_across_shards() {
    for &shards in &[1usize, 2, 8] {
        let s = shards as u64;
        let rack = rack(shards, 1);
        let entry = registry().iter().find(|e| e.name == "search").unwrap();
        let mut res = (entry.synth_load)(&rack, ROWS, DIMS, SEED);
        assert_eq!(res.cache_stats(), (0, 0), "cache born empty");
        // every shard consults the cache once per query; equal-shape
        // shards share one entry, so a fresh key synthesizes at least
        // once and at most once per distinct shard shape — concurrent
        // shards that lose the synthesis race count as hits
        res.query_seeded(0, SEED);
        let (h1, m1) = res.cache_stats();
        assert_eq!(h1 + m1, s, "shards={shards}: one lookup per shard");
        assert!(m1 >= 1, "shards={shards}: first query must synthesize");
        res.query_seeded(0, SEED);
        let (h2, m2) = res.cache_stats();
        assert_eq!(m2, m1, "shards={shards}: repeat must not re-synthesize");
        assert_eq!(
            h2,
            h1 + s,
            "shards={shards}: the repeat serves every shard's plan from cache"
        );
        // a new parameter index is a new key: misses must grow
        res.query_seeded(2, SEED);
        let (h3, m3) = res.cache_stats();
        assert!(m3 > m2, "shards={shards}: new params must synthesize");
        assert_eq!(h3 + m3, h2 + m2 + s, "shards={shards}: one lookup per shard");
    }
}

#[test]
fn invalidation_forces_resynthesis_without_changing_results() {
    let rack = rack(2, 1);
    for name in ["search", "ed", "hist"] {
        let entry = registry().iter().find(|e| e.name == name).unwrap();
        let mut res = (entry.synth_load)(&rack, rows_for(entry.dense), DIMS, SEED);
        let before = res.query_seeded(0, SEED);
        res.query_seeded(0, SEED);
        let (h, m) = res.cache_stats();
        assert!(h > 0 && m > 0, "{name}: warm-up should hit and miss");
        res.invalidate_cache();
        let after = res.query_seeded(0, SEED);
        let (h2, m2) = res.cache_stats();
        assert!(
            m2 > m,
            "{name}: post-invalidation query must re-synthesize (counters are \
             cumulative across invalidations)"
        );
        assert_eq!(h2 + m2, h + m + 2, "{name}: one lookup per shard");
        assert_eq!(before.bits, after.bits, "{name}: invalidation changed results");
        assert_eq!(before.fields, after.fields, "{name}: invalidation changed fields");
        assert_eq!(
            before.rack.total_cycles, after.rack.total_cycles,
            "{name}: invalidation changed the cycle ledger"
        );
    }
}

#[test]
fn batched_queries_share_cached_plans_with_repeats() {
    let rack = rack(2, 1);
    let entry = registry().iter().find(|e| e.name == "search").unwrap();
    let mut res = (entry.synth_load)(&rack, ROWS, DIMS, SEED);
    let a = res
        .query_seeded_batch(0, SEED, 4)
        .expect("search has a batched parameter stream");
    let (h1, m1) = res.cache_stats();
    assert!(m1 >= 1, "first batched query must synthesize");
    assert_eq!(h1 + m1, 2, "one lookup per shard");
    let b = res
        .query_seeded_batch(0, SEED, 4)
        .expect("search has a batched parameter stream");
    let (h2, m2) = res.cache_stats();
    assert_eq!(m2, m1, "batched repeat must not re-synthesize");
    assert_eq!(h2, h1 + 2, "batched repeat serves every shard's plan from cache");
    assert_eq!(a.bits, b.bits, "batched repeat drifted");
    assert_eq!(a.rack.total_cycles, b.rack.total_cycles);
    // the packed sweep stays under the analytic unbatched floor
    let floor = res
        .query_floor_seeded_batch(0, SEED, 4)
        .expect("search reports an unbatched floor");
    assert!(
        a.rack.max_shard_cycles < floor,
        "batched device cycles {} must beat the unbatched floor {floor}",
        a.rack.max_shard_cycles
    );
}
