//! Wear-aware LRU eviction property tests over live TCP (DESIGN.md
//! §Serving, docs/PROTOCOL.md §Resident datasets): a `LOAD` into a full
//! 16-entry table evicts the least-recently-used dataset *among the
//! coldest-wear candidates*, reports it in the trailing `evicted=`
//! reply field, never lists an evicted id in `DATASETS`, and a re-LOAD
//! of the evicted dataset's parameters reproduces its replies
//! bit-identically (modulo the dataset id).

use prins::host::server::Server;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn ask(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> String {
    let mut line = String::new();
    writeln!(conn, "{req}").unwrap();
    assert!(
        reader.read_line(&mut line).unwrap() > 0,
        "connection dropped at {req:?}"
    );
    line.trim().to_string()
}

/// Strip the trailing `dataset=<id>` field (always emitted last on
/// resident-query replies) so replies from different dataset ids can be
/// compared bit-for-bit on everything else.
fn without_dataset_id(reply: &str, expect_id: u64) -> String {
    let suffix = format!(" dataset={expect_id}");
    let stripped = reply
        .strip_suffix(&suffix)
        .unwrap_or_else(|| panic!("reply missing {suffix:?}: {reply}"));
    stripped.to_string()
}

#[test]
fn victim_is_lru_among_coldest_wear_and_reload_is_bit_identical() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // fill the table: 15 write-free hist datasets (max per-row wear 2:
    // value + valid bit-columns at load, queries add zero writes) and
    // one dp dataset whose *queries* write the scratch row
    for i in 1..=15u64 {
        let r = ask(&mut conn, &mut reader, "LOAD HIST 40 1");
        assert!(r.starts_with(&format!("OK id={i} ")), "{r}");
    }
    let r = ask(&mut conn, &mut reader, "LOAD DP 16 4 2");
    assert!(r.starts_with("OK id=16 kind=dp"), "{r}");

    // heat up the dp dataset's wear (each query writes the hyperplane
    // into the scratch row), then touch every hist AFTER it — so the dp
    // dataset is strictly the least-recently-used entry in the table
    for seed in [5, 6, 7] {
        let q = ask(&mut conn, &mut reader, &format!("DP 16 {seed}"));
        assert!(q.contains("dataset=16"), "{q}");
    }
    let mut hist_replies = Vec::new();
    for id in 1..=15u64 {
        let q = ask(&mut conn, &mut reader, &format!("HIST {id}"));
        assert!(q.contains(&format!("dataset={id}")), "{q}");
        hist_replies.push(q);
    }

    // a pure-LRU evictor would now pick the dp dataset (oldest touch).
    // The wear-aware evictor must protect its hot rows and instead pick
    // the LRU among the equal-coldest-wear hists: id 1. The `evicted=`
    // field is pinned as the final field of the LOAD reply.
    let full = ask(&mut conn, &mut reader, "LOAD HIST 40 1");
    assert!(full.starts_with("OK id=17 "), "{full}");
    assert!(full.ends_with(" evicted=1"), "{full}");

    // the evicted id is gone: DATASETS never lists it, queries ERR
    let ds = ask(&mut conn, &mut reader, "DATASETS");
    assert!(ds.starts_with("OK count=16"), "{ds}");
    assert!(!ds.contains("ds=1:"), "evicted id still listed: {ds}");
    assert!(ds.contains("ds=16:dp:16:1"), "wear-hot dp evicted: {ds}");
    assert!(ds.contains("ds=17:hist:40:1"), "{ds}");
    assert!(ask(&mut conn, &mut reader, "HIST 1").starts_with("ERR"));

    // re-LOAD after eviction is bit-identical: synthesize the evicted
    // dataset's exact parameters again (drop the new id first so the
    // reload does not itself evict) and compare its reply to the one
    // recorded from id 1 before eviction, modulo the dataset id
    assert_eq!(ask(&mut conn, &mut reader, "DROP 17"), "OK dropped=17");
    let r = ask(&mut conn, &mut reader, "LOAD HIST 40 1");
    assert!(r.starts_with("OK id=18 ") && !r.contains("evicted="), "{r}");
    let requeried = ask(&mut conn, &mut reader, "HIST 18");
    assert_eq!(
        without_dataset_id(&requeried, 18),
        without_dataset_id(&hist_replies[0], 1),
        "re-LOAD after eviction must reproduce replies bit-identically"
    );

    // all 15 hist datasets were interchangeable: every recorded reply
    // agrees once the id field is stripped (sanity for the comparison)
    for (i, q) in hist_replies.iter().enumerate() {
        assert_eq!(
            without_dataset_id(q, i as u64 + 1),
            without_dataset_id(&hist_replies[0], 1)
        );
    }
    server.shutdown();
}

#[test]
fn recency_breaks_ties_at_equal_wear() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // 16 equal-wear hist datasets; touch every one except id 7
    for i in 1..=16u64 {
        let r = ask(&mut conn, &mut reader, "LOAD HIST 24 9");
        assert!(r.starts_with(&format!("OK id={i} ")), "{r}");
    }
    for id in 1..=16u64 {
        if id != 7 {
            let q = ask(&mut conn, &mut reader, &format!("HIST {id}"));
            assert!(q.starts_with("OK"), "{q}");
        }
    }
    let full = ask(&mut conn, &mut reader, "LOAD HIST 24 9");
    assert!(full.starts_with("OK id=17 "), "{full}");
    assert!(full.ends_with(" evicted=7"), "{full}");
    let ds = ask(&mut conn, &mut reader, "DATASETS");
    assert!(!ds.contains("ds=7:"), "{ds}");
    server.shutdown();
}

/// A dataset whose only traffic is shared-read queries must still count
/// as recently used: the server's default shared-read admission routes
/// every write-free resident query (batched included) through
/// `dispatch_shared`, and that path has to stamp `last_used` exactly
/// like exclusive dispatch — otherwise a read-hot dataset becomes the
/// eviction victim the moment the table fills.
#[test]
fn shared_read_only_hot_dataset_survives_eviction() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // 16 equal-wear search datasets (write-free queries → all traffic
    // below routes through the shared-read path on the default server)
    for i in 1..=16u64 {
        let r = ask(&mut conn, &mut reader, "LOAD SEARCH 40 9");
        assert!(r.starts_with(&format!("OK id={i} ")), "{r}");
    }
    // touch ids 2..=16 first, then id 1 LAST — with a *batched* shared
    // query, so the batched shared arm's recency stamp is what keeps it
    // resident. Were shared reads not stamping, id 1 would keep its
    // load-time stamp (the table minimum) and be evicted here.
    for id in 2..=16u64 {
        let q = ask(&mut conn, &mut reader, &format!("SEARCH {id} 100 5000"));
        assert!(q.starts_with("OK"), "{q}");
    }
    let hot = ask(&mut conn, &mut reader, "SEARCH 1 2 100 5000 6000 40000");
    assert!(hot.contains("batch=2") && hot.contains("dataset=1"), "{hot}");

    let full = ask(&mut conn, &mut reader, "LOAD SEARCH 40 9");
    assert!(full.starts_with("OK id=17 "), "{full}");
    // the true LRU is id 2 (first touch of the loop), not id 1
    assert!(full.ends_with(" evicted=2"), "{full}");
    let ds = ask(&mut conn, &mut reader, "DATASETS");
    assert!(ds.contains("ds=1:search:40:1"), "shared-read-hot dataset evicted: {ds}");
    assert!(!ds.contains("ds=2:"), "{ds}");
    server.shutdown();
}
