//! Reliability-layer integration suite (DESIGN.md §Reliability):
//!
//! * the fault layer at BER = 0 is **bit-identical** to the ideal path
//!   for every registered kernel, across serial/threaded backends and
//!   shard counts — turning reliability on must never change results
//!   unless faults actually fire;
//! * the fault stream is a pure function of the seed: same seed, same
//!   flips, same results, same fidelity report — on any backend;
//! * stuck-at cells survive scrub rewrites and surface as graceful
//!   degradation (residual faults + bounded retries), never a panic;
//! * malformed fault configs are rejected up front (rack F01 gate and
//!   `PrinsArray::enable_faults`);
//! * wear-leveling remap flattens a hot-row workload's wear imbalance
//!   while reads remain transparent through the indirection.

use prins::algorithms::kernel::{find_name, registry};
use prins::host::rack::PrinsRack;
use prins::isa::RowLayout;
use prins::rcam::{DeviceModel, ExecBackend, InterconnectModel, PrinsArray};
use prins::reliability::{FaultModel, StuckCell, MAX_QUERY_RETRIES};
use prins::storage::wear::wear_report;
use prins::storage::StorageManager;

const DIMS: usize = 2;
const SEED: u64 = 5;
const Q: usize = 2;

fn rack(workers: usize, shards: usize) -> PrinsRack {
    PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        ExecBackend::from_workers(workers),
        InterconnectModel::default(),
    )
}

#[test]
fn ber_zero_is_bit_identical_to_ideal_across_backends_and_shards() {
    for entry in registry() {
        let rows = if entry.dense { 32 } else { 64 };
        for workers in [1usize, 4] {
            for shards in [1usize, 2, 8] {
                let mut ideal = (entry.synth_load)(&rack(workers, shards), rows, DIMS, SEED);
                let faulty_rack = rack(workers, shards)
                    .with_fault(FaultModel::uniform(0.0, 99))
                    .unwrap();
                let mut faulty = (entry.synth_load)(&faulty_rack, rows, DIMS, SEED);
                for q in 0..Q {
                    let i = ideal.query_seeded(q, SEED);
                    let f = faulty.query_seeded(q, SEED);
                    assert_eq!(
                        i.bits, f.bits,
                        "{} w={workers} s={shards} q={q}: BER=0 diverged from ideal",
                        entry.name
                    );
                    assert!(i.fidelity.is_none(), "{}: ideal run reported fidelity", entry.name);
                    let fid = f.fidelity.expect("fault-layer query returned no fidelity");
                    assert_eq!(fid.fidelity, 1.0, "{}: BER=0 fidelity", entry.name);
                    assert_eq!(fid.injected, 0, "{}: BER=0 injected faults", entry.name);
                    assert_eq!(fid.residual, 0, "{}: BER=0 residual faults", entry.name);
                }
            }
        }
    }
}

#[test]
fn same_fault_seed_reproduces_bit_identically_on_any_backend() {
    let entry = find_name("hist").unwrap();
    let model = FaultModel::uniform(0.02, 123);
    let run = |workers: usize| {
        let r = rack(workers, 1).with_fault(model.clone()).unwrap();
        let mut res = (entry.synth_load)(&r, 64, DIMS, SEED);
        (0..3)
            .map(|q| {
                let out = res.query_seeded(q, SEED);
                (out.bits, out.fidelity.unwrap())
            })
            .collect::<Vec<_>>()
    };
    let a = run(1);
    let b = run(1);
    let c = run(4);
    assert_eq!(a, b, "same seed, same backend: fault stream must replay exactly");
    assert_eq!(a, c, "faulted arrays run serial regardless of backend");
    let injected: u64 = a.iter().map(|(_, f)| f.injected).sum();
    assert!(injected > 0, "BER=0.02 over 64 rows must inject something");
}

#[test]
fn stuck_cells_degrade_gracefully_with_residual_and_bounded_retries() {
    // stick the valid bit (col 32 of the hist layout) of row 0 at 0: the
    // scrubber detects the mismatch every pass, the rewrite cannot take,
    // and the query ends with residual faults after bounded retries
    let entry = find_name("hist").unwrap();
    let model = FaultModel::uniform(0.0, 7).with_stuck(vec![StuckCell {
        row: 0,
        col: 32,
        value: false,
    }]);
    let r = rack(1, 1).with_fault(model).unwrap();
    let mut res = (entry.synth_load)(&r, 64, DIMS, SEED);
    let out = res.query_seeded(0, SEED);
    let fid = out.fidelity.expect("fault-layer query returned no fidelity");
    assert!(fid.detected >= 1, "scrub must detect the stuck valid bit: {fid:?}");
    assert!(fid.residual >= 1, "a stuck cell cannot be repaired: {fid:?}");
    assert_eq!(
        fid.retries, MAX_QUERY_RETRIES,
        "retries must stop at the bound, not loop forever: {fid:?}"
    );
    assert!(fid.overhead_cycles > 0, "scrub and backoff are charged work");
}

#[test]
fn malformed_fault_configs_are_rejected_up_front() {
    assert!(PrinsRack::new(1).with_fault(FaultModel::uniform(1.5, 1)).is_err());
    assert!(PrinsRack::new(1).with_fault(FaultModel::uniform(f64::NAN, 1)).is_err());
    assert!(PrinsRack::new(1).with_fault(FaultModel::uniform(0.01, 1)).is_ok());

    // the array-level F01 gate catches what the rack cannot know: stuck
    // cells outside the concrete shard shape
    let mut array = PrinsArray::single(8, 16);
    let bad_row = FaultModel::uniform(0.0, 1).with_stuck(vec![StuckCell {
        row: 99,
        col: 0,
        value: true,
    }]);
    assert!(array.enable_faults(bad_row).is_err());
    let bad_col = FaultModel::uniform(0.0, 1).with_stuck(vec![StuckCell {
        row: 0,
        col: 16,
        value: true,
    }]);
    assert!(array.enable_faults(bad_col).is_err());
    assert!(!array.has_faults(), "rejected configs must not half-enable");
}

#[test]
fn remap_flattens_hot_row_wear_and_stays_transparent() {
    let hammers = 200usize;
    let setup = || {
        let mut array = PrinsArray::single(32, 16);
        array.enable_wear_tracking();
        let mut sm = StorageManager::new(32);
        let mut layout = RowLayout::new(16);
        layout.alloc("v", 8);
        let ds = sm.alloc(16, layout).unwrap();
        (array, sm, ds)
    };

    // baseline: all writes land on logical row 3's fixed physical row
    let (mut array, sm, ds) = setup();
    for i in 0..hammers {
        sm.load_value(&mut array, &ds, 3, "v", i as u64 & 0xff).unwrap();
    }
    let flat = wear_report(&array).unwrap();

    // remap + periodic leveling: the hot logical row rotates across
    // cold physical rows
    let (mut array, mut sm, ds) = setup();
    sm.enable_remap();
    for i in 0..hammers {
        sm.load_value(&mut array, &ds, 3, "v", i as u64 & 0xff).unwrap();
        if i % 10 == 9 {
            sm.wear_level_step(&mut array);
        }
    }
    let leveled = wear_report(&array).unwrap();
    assert!(
        leveled.max_writes < flat.max_writes,
        "leveling must cap the hottest row: {} vs {}",
        leveled.max_writes,
        flat.max_writes
    );
    assert!(
        leveled.imbalance < flat.imbalance,
        "leveling must flatten imbalance: {} vs {}",
        leveled.imbalance,
        flat.imbalance
    );
    // the indirection is invisible to readers
    let got = sm.read_value(&array, &ds, 3, "v").unwrap();
    assert_eq!(got, (hammers as u64 - 1) & 0xff);
    sm.remap().unwrap().assert_consistent();
}
