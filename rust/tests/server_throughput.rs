//! Soak/stress battery for the multiplexed server (DESIGN.md §Serving):
//! N concurrent clients × Q pipelined queries over one **shared**
//! resident dataset — the table is server-wide (docs/PROTOCOL.md
//! §Sharing), so a setup connection loads once and every client queries
//! the same id. Every reply is asserted byte-identical to a
//! single-client serial reference session: concurrency, pipelining,
//! shared-read admission, and cross-connection coalescing must never
//! change a reply bit — plus zero dropped connections and consistent
//! ledger windows after the storm.

use prins::host::server::{ServeOptions, Server};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

/// Serial reference: one connection, strict request/reply lockstep.
fn ask_serially(addr: std::net::SocketAddr, script: &[&str]) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut replies = Vec::with_capacity(script.len());
    let mut line = String::new();
    for req in script {
        line.clear();
        writeln!(conn, "{req}").unwrap();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "serial reference dropped at {req:?}"
        );
        replies.push(line.trim().to_string());
    }
    replies
}

/// Fire the whole script as one pipelined burst and collect every reply.
fn ask_pipelined(addr: std::net::SocketAddr, script: &[&str]) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let burst: String = script.iter().map(|r| format!("{r}\n")).collect();
    conn.write_all(burst.as_bytes()).unwrap();
    let mut replies = Vec::with_capacity(script.len());
    let mut line = String::new();
    for req in script {
        line.clear();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "pipelined connection dropped at {req:?}"
        );
        replies.push(line.trim().to_string());
    }
    replies
}

/// Load one dataset into the fresh server's shared table from a setup
/// connection; on a fresh server the first load is always id 1, which
/// the client scripts reference directly.
fn load_once(addr: std::net::SocketAddr, load_line: &str) {
    let replies = ask_serially(addr, &[load_line, "QUIT"]);
    assert!(replies[0].starts_with("OK id=1 "), "{}", replies[0]);
}

/// The soak driver: `clients` threads each run `script` as a pipelined
/// burst against `server`, and every thread's replies must equal the
/// serial single-client reference, reply for reply.
fn soak(server: &Server, clients: usize, script: &[&str]) {
    let reference = ask_serially(server.addr, script);
    let barrier = Arc::new(Barrier::new(clients));
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(clients);
        for _ in 0..clients {
            let (reference, barrier) = (&reference, barrier.clone());
            handles.push(s.spawn(move || {
                barrier.wait(); // maximize overlap
                let got = ask_pipelined(server.addr, script);
                assert_eq!(got.len(), reference.len(), "dropped replies");
                for (i, (g, r)) in got.iter().zip(reference).enumerate() {
                    assert_eq!(g, r, "reply {i} ({:?}) diverged under load", script[i]);
                }
            }));
        }
        for h in handles {
            h.join().expect("soak client panicked");
        }
    });
}

/// The scripted session used across client counts: shared reads of the
/// pre-loaded hist dataset with a `DATASETS` listing in the middle —
/// its `count=`/`epoch=` fields are pinned by the single setup load, so
/// it too must stay byte-stable under the storm.
fn hist_script() -> Vec<&'static str> {
    let mut s = vec!["PING"];
    s.extend(std::iter::repeat("HIST 1").take(8));
    s.push("DATASETS");
    s.extend(std::iter::repeat("HIST 1").take(8));
    s.push("QUIT");
    s
}

fn hist_server() -> Server {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    load_once(server.addr, "LOAD HIST 300 5");
    server
}

#[test]
fn soak_4_clients_bit_equal_to_serial_reference() {
    let server = hist_server();
    soak(&server, 4, &hist_script());
    server.shutdown();
}

#[test]
fn soak_16_clients_bit_equal_to_serial_reference() {
    let server = hist_server();
    soak(&server, 16, &hist_script());
    server.shutdown();
}

#[test]
fn soak_64_clients_bit_equal_to_serial_reference() {
    let server = hist_server();
    soak(&server, 64, &hist_script());
    server.shutdown();
}

#[test]
fn soak_search_kernel_and_single_worker_server() {
    // the coalescable shared-read kernel (concurrent clients firing the
    // same pipelined SEARCH burst is exactly the shape the
    // cross-connection coalescer merges), and the degenerate pool: one
    // worker must still serve pipelined concurrent clients correctly
    let script = vec![
        "SEARCH 1 100 5000",
        "SEARCH 1 0 4294967295",
        "SEARCH 1 100 5000",
        "SEARCH 1 7 7",
        "SEARCH 1 100 5000",
        "QUIT",
    ];
    let server = Server::spawn("127.0.0.1:0").unwrap();
    load_once(server.addr, "LOAD SEARCH 400 9");
    soak(&server, 16, &script);
    server.shutdown();

    let one = Server::spawn_opts(
        "127.0.0.1:0",
        ServeOptions {
            workers: 1,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    load_once(one.addr, "LOAD SEARCH 400 9");
    soak(&one, 8, &script);
    one.shutdown();
}

#[test]
fn ledger_windows_stay_consistent_after_the_storm() {
    // after a soak, a fresh session's resident queries must still
    // repeat bit-identically and match the pre-storm reference: no
    // cross-session ledger or cycle leakage through the shared table
    let server = hist_server();
    let script = ["HIST 1", "HIST 1"];
    let before = ask_serially(server.addr, &script);
    soak(&server, 16, &hist_script());
    let after = ask_serially(server.addr, &script);
    assert_eq!(before, after, "dataset state leaked across the soak");
    assert_eq!(after[1], after[2], "resident query stopped repeating");
    server.shutdown();
}
