//! Soak/stress battery for the multiplexed server (DESIGN.md §Serving):
//! N concurrent clients × Q pipelined queries over resident datasets,
//! with every reply asserted byte-identical to a single-client serial
//! reference session — concurrency, pipelining, and shared-read
//! admission must never change a reply bit — plus zero dropped
//! connections and consistent ledger windows after the storm.

use prins::host::server::{ServeOptions, Server};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

/// Serial reference: one connection, strict request/reply lockstep.
fn ask_serially(addr: std::net::SocketAddr, script: &[&str]) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut replies = Vec::with_capacity(script.len());
    let mut line = String::new();
    for req in script {
        line.clear();
        writeln!(conn, "{req}").unwrap();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "serial reference dropped at {req:?}"
        );
        replies.push(line.trim().to_string());
    }
    replies
}

/// Fire the whole script as one pipelined burst and collect every reply.
fn ask_pipelined(addr: std::net::SocketAddr, script: &[&str]) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let burst: String = script.iter().map(|r| format!("{r}\n")).collect();
    conn.write_all(burst.as_bytes()).unwrap();
    let mut replies = Vec::with_capacity(script.len());
    let mut line = String::new();
    for req in script {
        line.clear();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "pipelined connection dropped at {req:?}"
        );
        replies.push(line.trim().to_string());
    }
    replies
}

/// The soak driver: `clients` threads each run `script` as a pipelined
/// burst against `server`, and every thread's replies must equal the
/// serial single-client reference, reply for reply.
fn soak(server: &Server, clients: usize, script: &[&str]) {
    let reference = ask_serially(server.addr, script);
    let barrier = Arc::new(Barrier::new(clients));
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(clients);
        for _ in 0..clients {
            let (reference, barrier) = (&reference, barrier.clone());
            handles.push(s.spawn(move || {
                barrier.wait(); // maximize overlap
                let got = ask_pipelined(server.addr, script);
                assert_eq!(got.len(), reference.len(), "dropped replies");
                for (i, (g, r)) in got.iter().zip(reference).enumerate() {
                    assert_eq!(g, r, "reply {i} ({:?}) diverged under load", script[i]);
                }
            }));
        }
        for h in handles {
            h.join().expect("soak client panicked");
        }
    });
}

/// The scripted session used across client counts: a resident hist
/// dataset (write-free → shared-read admitted), a burst of queries, an
/// exclusive DATASETS fence in the middle, and more shared reads after.
fn hist_script() -> Vec<&'static str> {
    let mut s = vec!["LOAD HIST 300 5", "PING"];
    s.extend(std::iter::repeat("HIST 1").take(8));
    s.push("DATASETS");
    s.extend(std::iter::repeat("HIST 1").take(8));
    s.push("QUIT");
    s
}

#[test]
fn soak_4_clients_bit_equal_to_serial_reference() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    soak(&server, 4, &hist_script());
    server.shutdown();
}

#[test]
fn soak_16_clients_bit_equal_to_serial_reference() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    soak(&server, 16, &hist_script());
    server.shutdown();
}

#[test]
fn soak_64_clients_bit_equal_to_serial_reference() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    soak(&server, 64, &hist_script());
    server.shutdown();
}

#[test]
fn soak_search_kernel_and_single_worker_server() {
    // the second shared-read kernel, and the degenerate pool: one
    // worker must still serve pipelined concurrent clients correctly
    let script = vec![
        "LOAD SEARCH 400 9",
        "SEARCH 1 100 5000",
        "SEARCH 1 0 4294967295",
        "SEARCH 1 100 5000",
        "SEARCH 1 7 7",
        "SEARCH 1 100 5000",
        "QUIT",
    ];
    let server = Server::spawn("127.0.0.1:0").unwrap();
    soak(&server, 16, &script);
    server.shutdown();

    let one = Server::spawn_opts(
        "127.0.0.1:0",
        ServeOptions {
            workers: 1,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    soak(&one, 8, &script);
    one.shutdown();
}

#[test]
fn ledger_windows_stay_consistent_after_the_storm() {
    // after a soak, a fresh session's resident queries must still
    // repeat bit-identically and match the pre-storm reference: no
    // cross-session ledger or cycle leakage through the shared pool
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let script = ["LOAD HIST 300 5", "HIST 1", "HIST 1"];
    let before = ask_serially(server.addr, &script);
    soak(&server, 16, &hist_script());
    let after = ask_serially(server.addr, &script);
    assert_eq!(before, after, "session state leaked across the soak");
    assert_eq!(after[1], after[2], "resident query stopped repeating");
    server.shutdown();
}
