//! Tentpole acceptance suite for resident datasets (load-once /
//! query-many, DESIGN.md §Resident datasets), registry-driven since the
//! ISSUE 5 kernel framework: for **every kernel in the registry** —
//! hist, dp, ed, spmv, search, and whatever is registered next, with
//! zero per-kernel test code — query #2..Q on a resident dataset must
//! produce bit-identical results to a freshly loaded one-shot run while
//! charging zero load-phase writes: each query's per-shard stats window
//! contains exactly the query program, never a reload.

use prins::algorithms::registry;
use prins::controller::ExecStats;
use prins::host::rack::PrinsRack;

const Q: usize = 5;

/// Two stats windows are the same work: cycles and the full event ledger.
fn assert_same_stats(a: &ExecStats, b: &ExecStats, what: &str) {
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.ledger, b.ledger, "{what}: ledger");
}

#[test]
fn queries_bit_identical_and_reload_free_for_every_registered_kernel() {
    let (n, dims, seed) = (40usize, 3usize, 5u64);
    for entry in registry() {
        for shards in [1usize, 3] {
            let rack = PrinsRack::new(shards);
            let mut res = (entry.synth_load)(&rack, n, dims, seed);
            let label = format!("{} shards={shards}", entry.name);
            let load_writes: u64 = res
                .load_report()
                .shard_stats
                .iter()
                .map(|s| s.ledger.n_write)
                .sum();
            assert!(load_writes > 0, "{label}: load phase must write the rows");
            // exact anchor: one charged write per stored field, no more —
            // a double-load in the generic Resident::load would trip this
            assert_eq!(
                load_writes,
                res.expected_load_writes(),
                "{label}: load wrote off the per-field floor"
            );

            // one-shot reference: a fresh load queried once with the
            // same parameter stream index
            let mut fresh = (entry.synth_load)(&rack, n, dims, seed);
            let one_shot = fresh.query_seeded(0, seed);

            let mut prev: Option<Vec<ExecStats>> = None;
            for q in 0..Q {
                // same parameter index every time: query #2..Q must be
                // bit-identical to query #1 and to the one-shot
                let r = res.query_seeded(0, seed);
                assert_eq!(r.bits, one_shot.bits, "{label} query={q}: diverged from one-shot");
                assert_eq!(r.fields, one_shot.fields, "{label} query={q}");
                for (i, st) in r.rack.shard_stats.iter().enumerate() {
                    assert_same_stats(
                        st,
                        &one_shot.rack.shard_stats[i],
                        &format!("{label} query={q} shard={i} vs one-shot"),
                    );
                    if let Some(p) = &prev {
                        assert_same_stats(
                            st,
                            &p[i],
                            &format!("{label} query={q} shard={i} vs previous query"),
                        );
                    }
                    if entry.write_free_queries {
                        assert_eq!(st.ledger.n_write, 0, "{label}: queries must never write");
                        assert_eq!(st.ledger.write_bit_events, 0, "{label}");
                    }
                }
                prev = Some(r.rack.shard_stats.clone());
            }

            // fresh parameters (a different stream index) still run
            // against the same resident rows without a reload spike:
            // the per-shard write counts stay at the steady query level
            let steady_writes: u64 = prev
                .as_ref()
                .unwrap()
                .iter()
                .map(|st| st.ledger.n_write)
                .sum();
            let r2 = res.query_seeded(1, seed);
            let w2: u64 = r2.rack.shard_stats.iter().map(|st| st.ledger.n_write).sum();
            assert!(
                w2 < steady_writes + load_writes,
                "{label}: fresh-parameter query wrote like a reload ({w2} vs steady {steady_writes} + load {load_writes})"
            );
        }
    }
}

#[test]
fn amortized_per_query_cycles_strictly_decrease_for_every_registered_kernel() {
    // The acceptance curve of BENCH_resident.json in miniature: with the
    // load phase charged once and a fixed query, (load + Σ query) / Q
    // strictly decreases in Q — for every registered kernel.
    for entry in registry() {
        let rack = PrinsRack::new(1);
        let mut res = (entry.synth_load)(&rack, 48, 2, 17);
        let load = res.load_report().total_cycles;
        assert!(load > 0, "{}: load phase must be charged", entry.name);
        let mut amortized = Vec::new();
        for q_count in [1usize, 4, 16] {
            let total: u64 = (0..q_count)
                .map(|_| res.query_seeded(0, 17).rack.total_cycles)
                .sum();
            amortized.push((load + total) as f64 / q_count as f64);
        }
        for w in amortized.windows(2) {
            assert!(
                w[1] < w[0],
                "{}: amortized cycles must strictly decrease: {amortized:?}",
                entry.name
            );
        }
    }
}
