//! Tentpole acceptance suite for resident datasets (load-once /
//! query-many, DESIGN.md §Resident datasets): for each of ED / DP /
//! HIST / SpMV, query #2..Q on a resident dataset must produce
//! bit-identical results to the one-shot path while charging zero
//! load-phase writes — each query's stats window contains exactly the
//! query program, never a reload.

use prins::algorithms::{
    dot_sharded, euclidean_sharded, histogram_baseline_at, histogram_sharded, spmv_sharded,
    ResidentDot, ResidentEuclidean, ResidentHistogram, ResidentSpmv,
};
use prins::controller::ExecStats;
use prins::host::rack::PrinsRack;
use prins::workloads::{synth_csr, synth_hist_samples, synth_samples, synth_uniform, Rng};

const Q: usize = 5;

/// Two stats windows are the same work: cycles and the full event ledger.
fn assert_same_stats(a: &ExecStats, b: &ExecStats, what: &str) {
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.ledger, b.ledger, "{what}: ledger");
}

#[test]
fn ed_queries_bit_identical_and_reload_free() {
    let (n, dims, k) = (40usize, 3usize, 2usize);
    let x = synth_samples(n, dims, 4, 5);
    let centers = synth_uniform(k * dims, 6);
    for shards in [1usize, 3] {
        let rack = PrinsRack::new(shards);
        let one_shot = euclidean_sharded(&rack, &x, n, dims, &centers, k, 2);
        let mut res = ResidentEuclidean::load(&rack, &x, n, dims);
        let load_writes: u64 = res
            .load_report()
            .shard_stats
            .iter()
            .map(|s| s.ledger.n_write)
            .sum();
        assert_eq!(load_writes, (n * dims) as u64, "one write per stored attribute");
        let mut prev = None;
        for q in 0..Q {
            let r = res.query(&centers, k, 2);
            for c in 0..k {
                assert!(
                    r.dists[c]
                        .iter()
                        .zip(&one_shot.dists[c])
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "shards={shards} query={q} center={c}: diverged from one-shot"
                );
            }
            assert_eq!(r.nearest, one_shot.nearest, "shards={shards} query={q}");
            for (i, st) in r.rack.shard_stats.iter().enumerate() {
                assert_same_stats(st, &one_shot.rack.shard_stats[i], "vs one-shot");
                if let Some(p) = &prev {
                    let p: &Vec<ExecStats> = p;
                    assert_same_stats(st, &p[i], "vs previous query");
                }
            }
            prev = Some(r.rack.shard_stats.clone());
        }
    }
}

#[test]
fn dp_queries_bit_identical_and_reload_free() {
    let (n, dims) = (48usize, 4usize);
    let x = synth_samples(n, dims, 4, 9);
    let h = synth_uniform(dims, 10);
    for shards in [1usize, 2] {
        let rack = PrinsRack::new(shards);
        let one_shot = dot_sharded(&rack, &x, n, dims, &h);
        let mut res = ResidentDot::load(&rack, &x, n, dims);
        for q in 0..Q {
            let r = res.query(&h);
            assert!(
                r.dp.iter().zip(&one_shot.dp).all(|(a, b)| a.to_bits() == b.to_bits()),
                "shards={shards} query={q}: diverged from one-shot"
            );
            for (st, os) in r.rack.shard_stats.iter().zip(&one_shot.rack.shard_stats) {
                assert_same_stats(st, os, "dp query window");
            }
        }
    }
}

#[test]
fn hist_queries_bit_identical_write_free_and_rebinnable() {
    let xs = synth_hist_samples(3000, 11);
    for shards in [1usize, 3] {
        let rack = PrinsRack::new(shards);
        let one_shot = histogram_sharded(&rack, &xs);
        let mut res = ResidentHistogram::load(&rack, &xs);
        for q in 0..Q {
            let r = res.query();
            assert_eq!(r.hist, one_shot.hist, "shards={shards} query={q}");
            for st in &r.rack.shard_stats {
                assert_eq!(st.ledger.n_write, 0, "histogram queries never write");
                assert_eq!(st.ledger.write_bit_events, 0);
            }
        }
        // new bin edges on the same resident samples
        for lo in [16u16, 8, 0] {
            assert_eq!(res.query_at(lo).hist, histogram_baseline_at(&xs, lo));
        }
    }
}

#[test]
fn spmv_queries_bit_identical_and_reload_free() {
    let a = synth_csr(56, 400, 13);
    let mut rng = Rng::seed_from(14);
    let x: Vec<f32> = (0..a.n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    for shards in [1usize, 2] {
        let rack = PrinsRack::new(shards);
        let one_shot = spmv_sharded(&rack, &a, &x);
        let mut res = ResidentSpmv::load(&rack, &a);
        let load_writes: u64 = res
            .load_report()
            .shard_stats
            .iter()
            .map(|s| s.ledger.n_write)
            .sum();
        assert_eq!(load_writes, 4 * a.nnz() as u64, "four writes per CSR nonzero");
        for q in 0..Q {
            let r = res.query(&x);
            assert!(
                r.y.iter().zip(&one_shot.y).all(|(p, s)| p.to_bits() == s.to_bits()),
                "shards={shards} query={q}: diverged from one-shot"
            );
            for (st, os) in r.rack.shard_stats.iter().zip(&one_shot.rack.shard_stats) {
                assert_same_stats(st, os, "spmv query window");
            }
        }
    }
}

#[test]
fn amortized_per_query_cycles_strictly_decrease() {
    // The acceptance curve of BENCH_resident.json in miniature: with the
    // load phase charged once, (load + Σ query) / Q strictly decreases.
    let xs = synth_hist_samples(2048, 17);
    let rack = PrinsRack::new(1);
    let mut res = ResidentHistogram::load(&rack, &xs);
    let load = res.load_report().total_cycles;
    assert!(load > 0, "load phase must be charged");
    let mut amortized = Vec::new();
    for q_count in [1usize, 4, 16, 64] {
        let total: u64 = (0..q_count).map(|_| res.query().rack.total_cycles).sum();
        amortized.push((load + total) as f64 / q_count as f64);
    }
    for w in amortized.windows(2) {
        assert!(w[1] < w[0], "amortized cycles must strictly decrease: {amortized:?}");
    }
}
