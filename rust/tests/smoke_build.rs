//! Build-resurrection smoke suite: proves the lib, controller, and host
//! layers actually link and run from a clean, network-less checkout, and
//! exercises the in-tree error module + manifest wiring end to end.

use prins::controller::kernels::KernelId;
use prins::controller::registers::Status;
use prins::error::{bail, ensure, err, Context, Error, Result};
use prins::host::PrinsDevice;
use prins::runtime::Manifest;
use prins::workloads::synth_hist_samples;

/// Lib + controller + host linked together: construct a device, run one
/// HIST kernel through the register protocol, and check the perf
/// counters carry real (nonzero) cycle/energy numbers.
#[test]
fn device_runs_hist_kernel_with_nonzero_cycles_and_energy() {
    let xs = synth_hist_samples(4096, 11);
    let dev = PrinsDevice::new(4096, 64);
    dev.load_samples_for_histogram(&xs);
    let st = dev.run_kernel(KernelId::Histogram, &[], &[]);
    assert_eq!(st, Status::Done);
    let out = dev.take_outputs();
    assert!(out.cycles > 0, "kernel must consume device cycles");
    assert!(out.energy_j > 0.0, "kernel must consume energy");
    assert_eq!(out.u64s.iter().sum::<u64>(), 4096, "every sample binned");
    assert_eq!(dev.regs.read_result(0), out.cycles);
}

fn parse_port(s: &str) -> Result<u16> {
    let p: u16 = s.parse().context("port")?;
    ensure!(p != 0, "port must be nonzero");
    if p < 1024 {
        bail!("privileged port {p}");
    }
    Ok(p)
}

#[test]
fn error_module_covers_the_anyhow_surface() {
    assert_eq!(parse_port("7411").unwrap(), 7411);
    assert!(parse_port("x").unwrap_err().to_string().starts_with("port:"));
    assert_eq!(
        parse_port("0").unwrap_err().to_string(),
        "port must be nonzero"
    );
    assert_eq!(
        parse_port("80").unwrap_err().to_string(),
        "privileged port 80"
    );
    let e: Error = err!("v={}", 7);
    assert_eq!(e.to_string(), "v=7");
    assert_eq!(format!("{e:#}"), "v=7");
    let io = std::io::Error::new(std::io::ErrorKind::NotFound, "boom");
    let e: Error = io.into();
    assert!(e.to_string().contains("boom"));
    let missing: Option<u32> = None;
    let e = missing.context("missing key").unwrap_err();
    assert_eq!(e.to_string(), "missing key");
}

#[test]
fn unknown_field_lookup_propagates_as_error() {
    let mut layout = prins::isa::RowLayout::new(32);
    layout.alloc("a", 8);
    let mut sm = prins::storage::StorageManager::new(16);
    let mut array = prins::rcam::PrinsArray::single(16, 32);
    let ds = sm.alloc(8, layout).unwrap();
    sm.load_value(&mut array, &ds, 0, "a", 5).unwrap();
    assert_eq!(sm.read_value(&array, &ds, 0, "a").unwrap(), 5);
    let e = sm.read_value(&array, &ds, 0, "nope").unwrap_err();
    assert!(e.to_string().contains("unknown field"), "{e}");
}

#[test]
fn manifest_parses_and_runtime_reports_missing_artifacts() {
    let text = r#"{
        "W": 256, "NW": 2048, "P": 128, "BLOCK_WORDS": 256,
        "GOLDEN_N": 4096, "GOLDEN_D": 16, "SPMV_NNZ": 16384,
        "SPMV_NB": 1024, "HIST_N": 65536,
        "entry_points": {
            "golden_ed": {
                "file": "golden_ed.hlo.txt", "outputs": 1,
                "args": [{"shape": [4096, 16], "dtype": "float32"}]
            }
        }
    }"#;
    let m = Manifest::parse(text).unwrap();
    assert_eq!(m.w, 256);
    assert_eq!(m.entry_points["golden_ed"].args[0].shape, vec![4096, 16]);

    // A fresh checkout has no artifacts/: Runtime::open must fail with a
    // pointed message, never panic — that is the skip path every
    // runtime consumer takes.
    let e = prins::runtime::Runtime::open("definitely-not-a-directory").unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("manifest"), "{msg}");
}
