//! Controller-level integration: program execution semantics, the
//! assembly path (text → program → execution), and stats windows.

use prins::controller::{Controller, READ_NO_MATCH};
use prins::isa::asm::parse_program;
use prins::isa::{Field, Instr, Program};
use prins::micro;
use prins::rcam::PrinsArray;

#[test]
fn assembly_text_executes_like_built_program() {
    // hand-written assembly for: tag rows with col0==1, write col3=1,
    // count them
    let text = "
        # tag then mark then count
        compare c0=1
        write   c3=1
        compare c3=1
        reduce
    ";
    let prog = parse_program(text).unwrap();
    let mut ctl = Controller::new(PrinsArray::single(64, 8));
    for r in [3usize, 7, 40] {
        ctl.array.load_row_bits(r, 0, 1, 1);
    }
    let out = ctl.execute_collect(&prog);
    assert_eq!(out, vec![3]);
}

#[test]
fn generated_microcode_survives_assembly_roundtrip_and_runs() {
    let (a, b) = (Field::new(0, 8), Field::new(8, 8));
    let mut prog = Program::new();
    micro::add_inplace(&mut prog, a, b, 20);
    let text = prins::isa::asm::format_program(&prog);
    let prog2 = parse_program(&text).unwrap();
    let mut ctl = Controller::new(PrinsArray::single(8, 24));
    ctl.array.load_row_bits(0, 0, 8, 99);
    ctl.array.load_row_bits(0, 8, 8, 28);
    ctl.execute(&prog2);
    assert_eq!(ctl.array.fetch_row_bits(0, 0, 8), 127);
}

#[test]
fn buffer_ordering_with_interleaved_reads_and_reduces() {
    let mut ctl = Controller::new(PrinsArray::single(32, 16));
    for r in 0..5 {
        ctl.array.load_row_bits(r, 0, 4, 0xA);
        ctl.array.load_row_bits(r, 4, 8, 0x10 + r as u64);
    }
    let mut p = Program::new();
    p.compare_field(Field::new(0, 4), 0xA);
    p.push(Instr::ReduceCount); // 5
    p.push(Instr::FirstMatch);
    p.push(Instr::Read { base: 4, width: 8 }); // 0x10
    p.push(Instr::ReduceCount); // 1 (only first tag remains)
    p.compare_field(Field::new(0, 4), 0x3);
    p.push(Instr::Read { base: 4, width: 8 }); // sentinel
    let out = ctl.execute_collect(&p);
    assert_eq!(out, vec![5, 0x10, 1, READ_NO_MATCH]);
}

#[test]
fn stats_windows_are_additive() {
    let mut ctl = Controller::new(PrinsArray::single(128, 16));
    let f = Field::new(0, 8);
    let mut p = Program::new();
    micro::flag_lt_const(&mut p, f, 100, 10);

    ctl.begin_stats();
    ctl.execute(&p);
    let s1 = ctl.stats();
    ctl.begin_stats();
    ctl.execute(&p);
    ctl.execute(&p);
    let s2 = ctl.stats();
    assert_eq!(s2.cycles, 2 * s1.cycles);
    assert_eq!(s2.passes, 2 * s1.passes);
    assert_eq!(
        s2.ledger.compare_bit_events,
        2 * s1.ledger.compare_bit_events
    );
}

#[test]
fn energy_model_tracks_pattern_width_and_tag_population() {
    let dev = prins::rcam::DeviceModel::default();
    let mut ctl = Controller::new(PrinsArray::single(1000, 16));
    // tag 10 rows, write 4 columns: write energy = 40 bit-events
    for r in 0..10 {
        ctl.array.load_row_bits(r, 0, 1, 1);
    }
    ctl.begin_stats();
    ctl.array.compare(&[(0, true)]); // full match line: 16 cols x 1000 rows
    ctl.array
        .write(&[(4, true), (5, false), (6, true), (7, true)]);
    let s = ctl.stats();
    assert_eq!(s.ledger.compare_bit_events, 16_000);
    assert_eq!(s.ledger.write_bit_events, 40);
    let e = s.ledger.dynamic_energy_j(&dev);
    // 16000 x 1fJ + 40 x 100fJ = 20 pJ
    assert!((e - 20.0e-12).abs() < 1e-15, "{e}");
}

#[test]
fn shift_instructions_through_program_path() {
    let mut ctl = Controller::new(PrinsArray::new(2, 8, 8));
    ctl.array.load_row_bits(7, 0, 1, 1); // last row of module 0
    let mut p = Program::new();
    p.push(Instr::Compare(vec![(0, true)]));
    p.push(Instr::ShiftTagsUp(2)); // crosses into module 1
    p.push(Instr::Write(vec![(5, true)]));
    ctl.execute(&p);
    assert_eq!(ctl.array.fetch_row_bits(9, 5, 1), 1);
    assert_eq!(ctl.array.fetch_row_bits(7, 5, 1), 0);
}
