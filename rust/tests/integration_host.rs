//! Host-interface integration: the register protocol end-to-end, repeated
//! kernels, the TCP server under concurrent clients, and failure paths.
//!
//! Every server in this suite binds 127.0.0.1:0 (kernel-assigned
//! ephemeral port) so parallel test runs can never collide on a fixed
//! port, and `Server::shutdown()` joins the acceptor and all connection
//! workers so no thread outlives its test.

use prins::algorithms::histogram_baseline;
use prins::controller::kernels::KernelId;
use prins::controller::registers::Status;
use prins::host::{server::Server, PrinsDevice};
use prins::workloads::{synth_hist_samples, synth_samples, synth_uniform};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

#[test]
fn repeated_kernels_on_one_device() {
    let xs = synth_hist_samples(1000, 1);
    let dev = PrinsDevice::new(1000, 64);
    dev.load_samples_for_histogram(&xs);
    let expect = histogram_baseline(&xs);
    for round in 0..3 {
        let st = dev.run_kernel(KernelId::Histogram, &[], &[]);
        assert_eq!(st, Status::Done, "round {round}");
        assert_eq!(dev.take_outputs().u64s, expect, "round {round}");
    }
    // completion counter advanced once per run
    assert_eq!(
        dev.regs
            .completions
            .load(std::sync::atomic::Ordering::Acquire),
        3
    );
}

#[test]
fn euclidean_through_device_with_params() {
    let (n, dims, k) = (64usize, 3usize, 2usize);
    let x = synth_samples(n, dims, k, 5);
    let centers = synth_uniform(k * dims, 6);
    let layout = prins::algorithms::euclidean::EuclideanLayout::new(dims);
    let dev = PrinsDevice::new(n, layout.width as usize);
    dev.load_samples_for_euclidean(&x, n, dims);
    let cp: Vec<f64> = centers.iter().map(|&v| v as f64).collect();
    let st = dev.run_kernel(KernelId::EuclideanDistance, &[k as u64], &cp);
    assert_eq!(st, Status::Done);
    let out = dev.take_outputs();
    assert_eq!(out.f32s.len(), n * k);
    let expect = prins::algorithms::euclidean_baseline(&x, n, dims, &centers, k);
    for c in 0..k {
        for i in 0..n {
            assert!(
                (out.f32s[c * n + i] - expect[c][i]).abs()
                    <= 3e-5 * expect[c][i].abs().max(1.0),
                "c={c} i={i}"
            );
        }
    }
    // perf counters surfaced via result registers
    assert_eq!(dev.regs.read_result(0), out.cycles);
}

#[test]
fn bad_parameter_count_is_an_error_not_a_hang() {
    let (n, dims) = (16usize, 2usize);
    let x = synth_samples(n, dims, 2, 7);
    let layout = prins::algorithms::euclidean::EuclideanLayout::new(dims);
    let dev = PrinsDevice::new(n, layout.width as usize);
    dev.load_samples_for_euclidean(&x, n, dims);
    // claim 2 centers but send coordinates for one
    let st = dev.run_kernel(KernelId::EuclideanDistance, &[2], &[0.0, 0.0]);
    assert_eq!(st, Status::Error);
    // device remains usable afterwards
    let st = dev.run_kernel(KernelId::EuclideanDistance, &[1], &[0.0, 0.0]);
    assert_eq!(st, Status::Done);
}

#[test]
fn tcp_server_concurrent_clients() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let addr = server.addr;
    let mut handles = Vec::new();
    for t in 0..3 {
        handles.push(std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            writeln!(conn, "HIST {} {}", 400 + t * 100, t).unwrap();
            reader.read_line(&mut line).unwrap();
            assert!(line.starts_with("OK"), "client {t}: {line}");
            assert!(line.contains(&format!("total={}", 400 + t * 100)));
            line.clear();
            writeln!(conn, "ED 128 2 2 {t}").unwrap();
            reader.read_line(&mut line).unwrap();
            assert!(line.starts_with("OK"), "client {t}: {line}");
            writeln!(conn, "QUIT").unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
}

#[test]
fn ephemeral_ports_cannot_collide_and_shutdown_joins_workers() {
    // Two servers up at once: the kernel hands each a distinct port.
    let a = Server::spawn("127.0.0.1:0").unwrap();
    let b = Server::spawn("127.0.0.1:0").unwrap();
    assert_ne!(a.addr.port(), 0, "bind resolved to a concrete port");
    assert_ne!(a.addr.port(), b.addr.port());
    // Leave a client connected and silent: shutdown must still join the
    // connection worker (it polls the stop flag) instead of hanging.
    let conn = TcpStream::connect(a.addr).unwrap();
    let mut check = TcpStream::connect(b.addr).unwrap();
    let mut reader = BufReader::new(check.try_clone().unwrap());
    writeln!(check, "PING").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "PONG");
    a.shutdown();
    b.shutdown();
    drop(conn);
}

#[test]
fn tcp_server_rejects_oversized_and_malformed() {
    let server = Server::spawn("127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    for bad in ["HIST 999999999 1", "HIST abc 1", "DP 10", "ED 0 1 1 1"] {
        line.clear();
        writeln!(conn, "{bad}").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{bad} -> {line}");
    }
    server.shutdown();
}
