//! Cross-module integration: every paper algorithm against its scalar
//! baseline on randomized workloads, through the full controller path.

use prins::algorithms::spmv::{ReduceEngine, SpmvKernel};
use prins::algorithms::{
    dot_baseline, euclidean_baseline, histogram_baseline, spmv_baseline_quantized,
    BfsKernel, DotKernel, EuclideanKernel, HistogramKernel,
};
use prins::controller::Controller;
use prins::rcam::PrinsArray;
use prins::storage::StorageManager;
use prins::workloads::{
    synth_csr, synth_hist_samples, synth_power_law, synth_rmat, synth_samples,
    synth_uniform, Rng,
};

#[test]
fn euclidean_multiple_centers() {
    let (n, dims, k) = (96usize, 4usize, 3usize);
    let x = synth_samples(n, dims, k, 51);
    let centers = synth_uniform(k * dims, 52);
    let layout = prins::algorithms::euclidean::EuclideanLayout::new(dims);
    let mut array = PrinsArray::new(3, n / 3, layout.width as usize);
    let mut sm = StorageManager::new(n);
    let kern = EuclideanKernel::load(&mut sm, &mut array, &x, n, dims);
    let mut ctl = Controller::new(array);
    let res = kern.run(&mut ctl, &sm, &centers, k);
    let expect = euclidean_baseline(&x, n, dims, &centers, k);
    for c in 0..k {
        for i in 0..n {
            assert!(
                (res.dists[c][i] - expect[c][i]).abs()
                    <= 3e-5 * expect[c][i].abs().max(1.0),
                "center {c} sample {i}"
            );
        }
    }
}

#[test]
fn dot_product_on_chain() {
    let (n, dims) = (64usize, 3usize);
    let x = synth_samples(n, dims, 2, 61);
    let h = synth_uniform(dims, 62);
    let layout = prins::algorithms::dot::DotLayout::new(dims);
    let mut array = PrinsArray::new(4, n / 4, layout.width as usize);
    let mut sm = StorageManager::new(n);
    let kern = DotKernel::load(&mut sm, &mut array, &x, n, dims);
    let mut ctl = Controller::new(array);
    let res = kern.run(&mut ctl, &sm, &h);
    let expect = dot_baseline(&x, n, dims, &h);
    for i in 0..n {
        assert!(
            (res.dp[i] - expect[i]).abs() <= 3e-5 * expect[i].abs().max(1.0),
            "dp[{i}]"
        );
    }
}

#[test]
fn histogram_structured_and_adversarial() {
    // structured bump
    let xs = synth_hist_samples(3000, 71);
    let mut array = PrinsArray::single(xs.len(), 40);
    let mut sm = StorageManager::new(xs.len());
    let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
    let mut ctl = Controller::new(array);
    assert_eq!(kern.run(&mut ctl).hist, histogram_baseline(&xs));

    // adversarial: all samples in one bin, and bin-boundary values
    let xs: Vec<u32> = vec![0xAB00_0000; 100]
        .into_iter()
        .chain([0x0000_0000, 0x00FF_FFFF, 0xFF00_0000, 0xFFFF_FFFF])
        .collect();
    let mut array = PrinsArray::single(xs.len(), 40);
    let mut sm = StorageManager::new(xs.len());
    let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
    let mut ctl = Controller::new(array);
    let h = kern.run(&mut ctl).hist;
    assert_eq!(h[0xAB], 100);
    assert_eq!(h[0x00], 2);
    assert_eq!(h[0xFF], 2);
}

#[test]
fn spmv_random_matrices_both_engines() {
    let mut rng = Rng::seed_from(81);
    for (n, nnz) in [(32usize, 150usize), (100, 600), (64, 1200)] {
        let a = synth_csr(n, nnz, rng.next_u64());
        let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let expect = spmv_baseline_quantized(&a, &x);
        for engine in [ReduceEngine::ChainTree, ReduceEngine::SerialTree] {
            let mut array = PrinsArray::single(a.nnz(), 256);
            let mut sm = StorageManager::new(a.nnz());
            let kern = SpmvKernel::load(&mut sm, &mut array, &a);
            let mut ctl = Controller::new(array);
            let res = kern.run(&mut ctl, &x, engine);
            for r in 0..n {
                assert!(
                    (res.y[r] - expect[r]).abs() < 1e-6,
                    "{engine:?} n={n} row {r}: {} vs {}",
                    res.y[r],
                    expect[r]
                );
            }
        }
    }
}

#[test]
fn bfs_on_rmat_and_power_law() {
    for g in [
        synth_rmat(9, 6.0, 91),
        synth_power_law(400, 8.0, 2.5, 92),
    ] {
        let (expect, _) = g.bfs(0);
        let mut array = PrinsArray::single(g.edges(), 128);
        let mut sm = StorageManager::new(g.edges());
        let kern = BfsKernel::load(&mut sm, &mut array, &g);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, 0);
        assert_eq!(res.dist, expect);
    }
}

#[test]
fn wear_accumulates_during_kernels() {
    let xs = synth_hist_samples(500, 99);
    let mut array = PrinsArray::single(xs.len(), 40);
    array.enable_wear_tracking();
    let mut sm = StorageManager::new(xs.len());
    let kern = HistogramKernel::load(&mut sm, &mut array, &xs);
    let mut ctl = Controller::new(array);
    kern.run(&mut ctl);
    let rep = prins::storage::wear::wear_report(&ctl.array).unwrap();
    // histogram never writes the array beyond the load: max wear == 2
    // (sample load + valid-flag load)
    assert_eq!(rep.max_writes, 2);
    let life = prins::storage::wear::projected_lifetime_s(
        &rep,
        ctl.device(),
        ctl.array.cycles,
    );
    assert!(life > 0.0);
}
