//! Randomized property tests over the coordinator's invariants (an
//! in-tree property harness — the vendored crate set has no proptest).
//! Each property runs hundreds of random cases from a deterministic seed.

use prins::controller::Controller;
use prins::isa::{Field, Instr, Program, RowLayout};
use prins::micro;
use prins::rcam::{ExecBackend, PrinsArray};
use prins::storage::StorageManager;
use prins::workloads::Rng;

/// Tag-logic invariants: first_match keeps exactly the first tag;
/// if_match ⇔ any tag; counts consistent.
#[test]
fn prop_tag_logic() {
    let mut rng = Rng::seed_from(0xA11CE);
    for case in 0..300 {
        let rows = 1 + rng.below(500) as usize;
        let modules = 1 + rng.below(4) as usize;
        let rpm = rows.div_ceil(modules);
        let mut arr = PrinsArray::new(modules, rpm, 8);
        let density = rng.below(100);
        let mut expected: Vec<usize> = Vec::new();
        for r in 0..arr.total_rows() {
            if rng.below(100) < density {
                arr.load_row_bits(r, 0, 1, 1);
                expected.push(r);
            }
        }
        arr.compare(&[(0, true)]);
        assert_eq!(arr.count_tags() as usize, expected.len(), "case {case}");
        let any = arr.if_match();
        assert_eq!(any, !expected.is_empty(), "case {case}");
        let fm = arr.first_match();
        assert_eq!(fm, expected.first().copied(), "case {case}");
        let snap = arr.tags_snapshot();
        assert_eq!(
            snap.iter_ones().collect::<Vec<_>>(),
            expected.first().copied().into_iter().collect::<Vec<_>>(),
            "case {case}: first_match keeps exactly the first tag"
        );
    }
}

/// Microcode arithmetic vs native integer semantics on random field
/// geometries and values.
#[test]
fn prop_fixed_point_arithmetic() {
    let mut rng = Rng::seed_from(0xBEEF);
    for case in 0..60 {
        let m = 2 + rng.below(12) as u16; // field width 2..13
        let a = Field::new(0, m);
        let b = Field::new(m, m);
        let p = Field::new(2 * m, 2 * m);
        let c_col = 4 * m + 1;
        let rows = 32;
        let op = rng.below(4);
        let mut prog = Program::new();
        match op {
            0 => micro::add_inplace(&mut prog, a, b, c_col),
            1 => micro::sub_inplace(&mut prog, a, b, c_col),
            2 => micro::mul(&mut prog, a, b, p, c_col),
            _ => micro::square(&mut prog, a, p, c_col),
        }
        let mut ctl = Controller::new(PrinsArray::single(rows, (4 * m + 2) as usize));
        let mask = (1u64 << m) - 1;
        let mut vals = Vec::new();
        for r in 0..rows {
            let av = rng.next_u64() & mask;
            let bv = rng.next_u64() & mask;
            ctl.array.load_row_bits(r, 0, m as usize, av);
            ctl.array.load_row_bits(r, m as usize, m as usize, bv);
            vals.push((av, bv));
        }
        ctl.execute(&prog);
        for (r, &(av, bv)) in vals.iter().enumerate() {
            match op {
                0 => assert_eq!(
                    ctl.array.fetch_row_bits(r, 0, m as usize),
                    (av + bv) & mask,
                    "case {case} add row {r}"
                ),
                1 => assert_eq!(
                    ctl.array.fetch_row_bits(r, 0, m as usize),
                    av.wrapping_sub(bv) & mask,
                    "case {case} sub row {r}"
                ),
                2 => assert_eq!(
                    ctl.array.fetch_row_bits(r, 2 * m as usize, 2 * m as usize),
                    av * bv,
                    "case {case} mul row {r}"
                ),
                _ => assert_eq!(
                    ctl.array.fetch_row_bits(r, 2 * m as usize, 2 * m as usize),
                    av * av,
                    "case {case} square row {r}"
                ),
            }
        }
    }
}

/// fp32 microcode vs hardware float semantics (≤ 4 ulp; truncation mode).
#[test]
fn prop_fp32_ops() {
    use prins::micro::float::{
        bits_to_f32, unpacked_bits, FloatField, FpScratch, FP_SCRATCH_BITS,
    };
    let mut rng = Rng::seed_from(0xF10A7);
    let x = FloatField::at(0);
    let y = FloatField::at(33);
    let z = FloatField::at(66);
    let s = FpScratch::at(100);
    let w = Field::new(100 + FP_SCRATCH_BITS, 8);
    let mut padd = Program::new();
    micro::float::fp_add(&mut padd, x, y, z, s, w);
    let mut pmul = Program::new();
    micro::float::fp_mul(&mut pmul, x, y, z, 172);
    let ulp = |a: f32, b: f32| -> u64 {
        if a == b {
            return 0;
        }
        let key = |v: f32| {
            let bits = v.to_bits();
            if bits >> 31 == 1 {
                -((bits & 0x7FFF_FFFF) as i64)
            } else {
                (bits & 0x7FFF_FFFF) as i64
            }
        };
        (key(a) - key(b)).unsigned_abs()
    };
    for round in 0..6 {
        let rows = 64;
        let mut ctl = Controller::new(PrinsArray::single(rows, 240));
        let mut cases = Vec::new();
        for r in 0..rows {
            // wide dynamic range, avoiding inf/denormal edges
            let e1 = rng.below(40) as i32 - 20;
            let e2 = rng.below(40) as i32 - 20;
            let a = rng.f32_range(-1.0, 1.0) * 2f32.powi(e1);
            let b = rng.f32_range(-1.0, 1.0) * 2f32.powi(e2);
            let (a, b) = (
                if a == 0.0 { 1.0 } else { a },
                if b == 0.0 { 1.0 } else { b },
            );
            ctl.array.load_row_bits(r, 0, 33, unpacked_bits(a));
            ctl.array.load_row_bits(r, 33, 33, unpacked_bits(b));
            cases.push((a, b));
        }
        let mul = round % 2 == 1;
        ctl.execute(if mul { &pmul } else { &padd });
        for (r, (a, b)) in cases.iter().enumerate() {
            let got = bits_to_f32(ctl.array.fetch_row_bits(r, 66, 33));
            let exact = if mul { a * b } else { a + b };
            assert!(
                ulp(got, exact) <= 4,
                "round {round} row {r}: {a} op {b} = {exact}, got {got}"
            );
        }
    }
}

/// Storage-manager invariants: allocations never overlap, frees recycle,
/// translation stays in-range.
#[test]
fn prop_storage_allocator() {
    let mut rng = Rng::seed_from(0x5107A6E);
    for _case in 0..200 {
        let total = 100 + rng.below(2000) as usize;
        let mut sm = StorageManager::new(total);
        let mut live: Vec<prins::storage::Dataset> = Vec::new();
        for _ in 0..30 {
            if rng.below(3) == 0 && !live.is_empty() {
                let i = rng.below(live.len() as u64) as usize;
                let ds = live.swap_remove(i);
                assert!(sm.free(ds.id));
            } else {
                let want = 1 + rng.below(300) as usize;
                if let Some(ds) = sm.alloc(want, RowLayout::new(64)) {
                    assert!(ds.rows.end() <= total);
                    live.push(ds);
                }
            }
            sm.assert_disjoint();
        }
        let allocated: usize = live.iter().map(|d| d.rows.len).sum();
        assert_eq!(sm.allocated_rows(), allocated);
    }
}

/// Chain equivalence: any random instruction stream gives identical
/// storage state and cycle counts on a multi-module chain and a flat
/// single-module array.
#[test]
fn prop_chain_flat_equivalence() {
    let mut rng = Rng::seed_from(0xC4A1);
    for case in 0..40 {
        let rows = 128;
        let width = 24;
        let modules = 2 + rng.below(3) as usize;
        let mut chain = PrinsArray::new(modules, rows / modules + 1, width);
        let mut flat = PrinsArray::single(chain.total_rows(), width);
        for r in 0..chain.total_rows() {
            let v = rng.next_u64() & 0xFFFFFF;
            chain.load_row_bits(r, 0, width, v);
            flat.load_row_bits(r, 0, width, v);
        }
        for _ in 0..30 {
            let mk_pat = |rng: &mut Rng| -> Vec<(u16, bool)> {
                let k = 1 + rng.below(4) as usize;
                let mut used = std::collections::HashSet::new();
                (0..k)
                    .filter_map(|_| {
                        let c = rng.below(width as u64) as u16;
                        used.insert(c).then_some((c, rng.below(2) == 1))
                    })
                    .collect()
            };
            match rng.below(4) {
                0 => {
                    let p = mk_pat(&mut rng);
                    chain.compare(&p);
                    flat.compare(&p);
                }
                1 => {
                    let p = mk_pat(&mut rng);
                    chain.write(&p);
                    flat.write(&p);
                }
                2 => {
                    assert_eq!(chain.count_tags(), flat.count_tags(), "case {case}");
                }
                _ => {
                    chain.first_match();
                    flat.first_match();
                    assert_eq!(
                        chain.tags_snapshot().iter_ones().collect::<Vec<_>>(),
                        flat.tags_snapshot().iter_ones().collect::<Vec<_>>()
                    );
                }
            }
        }
        for r in 0..chain.total_rows() {
            assert_eq!(
                chain.fetch_row_bits(r, 0, width),
                flat.fetch_row_bits(r, 0, width),
                "case {case} row {r}"
            );
        }
        assert_eq!(chain.cycles, flat.cycles, "SIMD cycle equivalence");
    }
}

/// Serial/parallel equivalence: for random programs over random arrays,
/// `ExecBackend::Serial` and `Threaded(n)` produce identical storage
/// contents, tag vectors, data buffers, cycle counts, and energy ledgers
/// — including worker counts whose word stripes do not divide module
/// rows evenly, and wear tracking on the striped write path.
#[test]
fn prop_serial_threaded_equivalence() {
    let mut rng = Rng::seed_from(0x57121BE5);
    for case in 0..30 {
        let modules = 1 + rng.below(4) as usize;
        // odd row counts => partial tail words and uneven stripe splits
        let rpm = 17 + rng.below(180) as usize;
        let width = 16usize;
        let wear = rng.below(2) == 1;
        let density = 1 + rng.below(99);

        // one random dataset, loaded identically into every array
        let total = modules * rpm;
        let mut data = Vec::with_capacity(total);
        for _ in 0..total {
            data.push(rng.next_u64() & 0xFFFF);
        }

        // one random program: data-parallel spans interleaved with
        // serializing instructions (reads, reductions, shifts)
        let mut prog = Program::new();
        let mk_pat = |rng: &mut Rng| -> Vec<(u16, bool)> {
            let k = 1 + rng.below(3) as usize;
            let mut used = std::collections::HashSet::new();
            (0..k)
                .filter_map(|_| {
                    let c = rng.below(width as u64) as u16;
                    used.insert(c).then_some((c, rng.below(2) == 1))
                })
                .collect()
        };
        for _ in 0..24 {
            match rng.below(10) {
                0 | 1 => prog.push(Instr::Compare(mk_pat(&mut rng))),
                2 | 3 => prog.push(Instr::Write(mk_pat(&mut rng))),
                4 => prog.push(Instr::SetTagsAll),
                5 => prog.push(Instr::ClearColumns {
                    base: rng.below(width as u64 - 1) as u16,
                    width: 1,
                }),
                6 => prog.push(Instr::ReduceCount),
                7 => prog.push(Instr::ReduceField {
                    col: rng.below(width as u64) as u16,
                }),
                8 => prog.push(match rng.below(3) {
                    0 => Instr::Read { base: 0, width: 8 },
                    1 => Instr::IfMatch,
                    _ => Instr::FirstMatch,
                }),
                _ => {
                    // hops occasionally exceed rows_per_module to hit the
                    // gathered-global shift fallback
                    let hops = 1 + rng.below(rpm as u64 + rpm as u64 / 2) as u32;
                    if rng.below(2) == 0 {
                        prog.push(Instr::ShiftTagsUp(hops));
                    } else {
                        prog.push(Instr::ShiftTagsDown(hops));
                    }
                }
            }
        }

        let run = |backend: ExecBackend| {
            let mut arr = PrinsArray::new(modules, rpm, width).with_backend(backend);
            if wear {
                arr.enable_wear_tracking();
            }
            let mut d = Rng::seed_from(case as u64);
            for (r, &v) in data.iter().enumerate() {
                if d.below(100) < density {
                    arr.load_row_bits(r, 0, width, v);
                }
            }
            let mut ctl = Controller::new(arr);
            let out = ctl.execute_collect(&prog);
            (ctl, out)
        };

        let (s, out_s) = run(ExecBackend::Serial);
        for n in [2usize, 3, 8] {
            let (t, out_t) = run(ExecBackend::Threaded(n));
            let label = format!("case {case} ({modules}x{rpm}) workers={n}");
            assert_eq!(out_s, out_t, "{label}: data buffer");
            assert_eq!(s.array.cycles, t.array.cycles, "{label}: cycles");
            assert_eq!(s.array.ledger(), t.array.ledger(), "{label}: ledger");
            assert_eq!(
                s.array.tags_snapshot().iter_ones().collect::<Vec<_>>(),
                t.array.tags_snapshot().iter_ones().collect::<Vec<_>>(),
                "{label}: tags"
            );
            for r in 0..total {
                assert_eq!(
                    s.array.fetch_row_bits(r, 0, width),
                    t.array.fetch_row_bits(r, 0, width),
                    "{label}: row {r}"
                );
            }
            for (ms, mt) in s.array.modules().iter().zip(t.array.modules()) {
                assert_eq!(ms.wear_counters(), mt.wear_counters(), "{label}: wear");
            }
        }
    }
}

/// The assembler round-trips every program the microcode generators emit.
#[test]
fn prop_assembler_roundtrip() {
    use prins::isa::asm::{format_program, parse_program};
    let mut rng = Rng::seed_from(0xA53);
    for _ in 0..20 {
        let m = 2 + rng.below(10) as u16;
        let a = Field::new(0, m);
        let b = Field::new(m, m);
        let p = Field::new(2 * m, 2 * m);
        let mut prog = Program::new();
        micro::mul(&mut prog, a, b, p, 4 * m + 1);
        micro::flag_lt_const(&mut prog, a, rng.below(1 << m), 4 * m + 2);
        let text = format_program(&prog);
        let parsed = parse_program(&text).expect("parse back");
        assert_eq!(prog, parsed);
    }
}
