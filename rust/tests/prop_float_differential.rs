//! Differential test suite for `micro/float.rs`: run the associative
//! `fp_add` / `fp_sub` / `fp_mul` microprograms against host `f32`
//! arithmetic over adversarial operand grids — subnormals, exponent
//! boundaries, ±0, NaN/Inf bit patterns, rounding ties — on both the
//! serial and the threaded simulator backends.
//!
//! Reference semantics (DESIGN.md substitution ledger): the microcode
//! deviates from IEEE-754 by flush-to-zero subnormals, round-toward-zero
//! truncation (≤ 4 ulp per operation), and exponent saturation instead
//! of Inf/NaN. The differential oracle therefore is:
//!   * both backends agree **bit-for-bit** on every pair (always);
//!   * for pairs whose FTZ'd operands are finite and whose exact result
//!     lies in the comfortably-normal range, the microcode result is
//!     within 4 ulp of host f32 arithmetic on the FTZ'd operands;
//!   * exact zeros (cancellation, ±0 inputs, zero products) come back as
//!     canonical zeros;
//!   * NaN/Inf bit patterns never panic the simulator and produce
//!     deterministic, backend-identical outputs (their unpacked form is
//!     a saturated finite value — documented, not IEEE).

use prins::controller::Controller;
use prins::micro::float::{
    bits_to_f32, fp_add, fp_mul, fp_sub, unpacked_bits, FloatField, FpScratch, FP_SCRATCH_BITS,
};
use prins::isa::{Field, Program};
use prins::rcam::{ExecBackend, PrinsArray};

/// Adversarial operand grid: zeros, subnormal extremes, normal extremes,
/// exponent boundaries, rounding ties, and non-finite bit patterns.
fn grid() -> Vec<f32> {
    vec![
        0.0,
        -0.0,
        1.0,
        -1.0,
        2.0,
        0.5,
        1.5,
        -1.5,
        // rounding ties / mantissa boundaries
        1.0 + f32::EPSILON,            // smallest > 1
        1.0 - f32::EPSILON / 2.0,      // largest < 1
        16_777_216.0,                  // 2^24: mantissa lsb = 1.0
        16_777_215.0,                  // 2^24 - 1: all-ones mantissa
        0.1,                           // repeating fraction
        -0.333_333_34,
        // exponent boundaries
        f32::MIN_POSITIVE,             // 2^-126, smallest normal
        -f32::MIN_POSITIVE,
        f32::from_bits(0x0080_0001),   // just above the subnormal border
        8.5e-20,
        1.0e20,
        f32::MAX,
        -f32::MAX,
        // subnormals (FTZ: behave as ±0)
        f32::from_bits(0x0000_0001),   // smallest positive subnormal
        f32::from_bits(0x007F_FFFF),   // largest subnormal
        -f32::from_bits(0x0040_0000),
        // non-finite bit patterns (saturation semantics, no panics)
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
    ]
}

/// Flush subnormals to (sign-preserving) zero — the microcode's storage
/// format does this on load.
fn ftz(v: f32) -> f32 {
    if v != 0.0 && v.is_finite() && v.abs() < f32::MIN_POSITIVE {
        if v.is_sign_negative() {
            -0.0
        } else {
            0.0
        }
    } else {
        v
    }
}

fn ulp_diff(a: f32, b: f32) -> u64 {
    if a == b || (a == 0.0 && b == 0.0) {
        return 0;
    }
    let key = |v: f32| {
        let b = v.to_bits();
        if b >> 31 == 1 {
            -((b & 0x7FFF_FFFF) as i64)
        } else {
            (b & 0x7FFF_FFFF) as i64
        }
    };
    (key(a) - key(b)).unsigned_abs()
}

/// Magnitude of one ulp at |v| (v normal): distance to the next float up.
fn ulp_of(v: f32) -> f32 {
    let a = v.abs();
    f32::from_bits(a.to_bits() + 1) - a
}

/// Whether the exact result is in the range where the 4-ulp contract
/// applies (clear of the saturation and flush-to-zero regions).
fn value_checkable(exact: f32) -> bool {
    exact == 0.0 || (exact.is_finite() && exact.abs() >= 1.0e-36 && exact.abs() <= 1.0e36)
}

/// Run `build(prog, x, y, z)` over all operand pairs on the given
/// backend; returns the per-pair raw 33-bit results.
fn run_microprogram(
    pairs: &[(f32, f32)],
    backend: ExecBackend,
    build: impl Fn(&mut Program, FloatField, FloatField, FloatField),
) -> Vec<u64> {
    let x = FloatField::at(0);
    let y = FloatField::at(33);
    let z = FloatField::at(66);
    let mut prog = Program::new();
    build(&mut prog, x, y, z);
    let mut c = Controller::new(PrinsArray::single(pairs.len(), 240).with_backend(backend));
    for (r, (a, b)) in pairs.iter().enumerate() {
        c.array.load_row_bits(r, 0, 33, unpacked_bits(*a));
        c.array.load_row_bits(r, 33, 33, unpacked_bits(*b));
    }
    c.execute(&prog);
    (0..pairs.len())
        .map(|r| c.array.fetch_row_bits(r, 66, 33))
        .collect()
}

/// The differential driver. `relative` selects the error contract:
/// multiplication carries the ≤ 4 ulp **relative** truncation bound (no
/// cancellation is possible); addition/subtraction without guard bits
/// carries the honest **absolute** bound of ≤ 4 ulp of the largest
/// participating magnitude — catastrophic cancellation across an
/// exponent boundary legitimately amplifies relative error, and a
/// relative oracle there would test IEEE semantics the hardware never
/// promised.
fn differential(
    op_name: &str,
    host: impl Fn(f32, f32) -> f32,
    relative: bool,
    build: impl Fn(&mut Program, FloatField, FloatField, FloatField) + Copy,
) {
    let g = grid();
    let pairs: Vec<(f32, f32)> = g
        .iter()
        .flat_map(|&a| g.iter().map(move |&b| (a, b)))
        .collect();
    let serial = run_microprogram(&pairs, ExecBackend::Serial, build);
    let threaded = run_microprogram(&pairs, ExecBackend::Threaded(3), build);
    for (r, (a, b)) in pairs.iter().enumerate() {
        // 1. backends agree bit-for-bit on every pair, special or not
        assert_eq!(
            serial[r], threaded[r],
            "{op_name} row {r} ({a:e}, {b:e}): serial/threaded diverge"
        );
        let (fa, fb) = (ftz(*a), ftz(*b));
        if !fa.is_finite() || !fb.is_finite() {
            continue; // saturation semantics: determinism asserted above
        }
        let got = bits_to_f32(serial[r]);
        let exact = host(fa, fb);
        if !value_checkable(exact) {
            continue; // saturation / underflow region
        }
        if exact == 0.0 {
            // 2. exact zeros come back canonical (±0)
            assert_eq!(
                got.abs().to_bits(),
                0,
                "{op_name} row {r} ({a:e}, {b:e}): expected canonical zero, got {got:e}"
            );
        } else if relative {
            // 3a. multiplication: ≤ 4 ulp relative
            assert!(
                ulp_diff(got, exact) <= 4,
                "{op_name} row {r}: {a:e} {op_name} {b:e} = {exact:e}, got {got:e} \
                 ({} ulp)",
                ulp_diff(got, exact)
            );
        } else {
            // 3b. add/sub: ≤ 4 ulp of the largest participating magnitude
            let maxmag = fa.abs().max(fb.abs()).max(exact.abs());
            let bound = 4.0 * ulp_of(maxmag);
            assert!(
                (got - exact).abs() <= bound,
                "{op_name} row {r}: {a:e} {op_name} {b:e} = {exact:e}, got {got:e} \
                 (err {:e} > bound {bound:e})",
                (got - exact).abs()
            );
        }
    }
}

#[test]
fn fp_add_differential_grid() {
    differential("add", |a, b| a + b, false, |p, x, y, z| {
        let s = FpScratch::at(100);
        let wexp = Field::new(100 + FP_SCRATCH_BITS, 8);
        fp_add(p, x, y, z, s, wexp);
    });
}

#[test]
fn fp_sub_differential_grid() {
    differential("sub", |a, b| a - b, false, |p, x, y, z| {
        let ycopy = FloatField::at(171);
        let s = FpScratch::at(100);
        let wexp = Field::new(100 + FP_SCRATCH_BITS, 8);
        fp_sub(p, x, y, z, ycopy, s, wexp);
    });
}

#[test]
fn fp_mul_differential_grid() {
    differential("mul", |a, b| a * b, true, |p, x, y, z| {
        fp_mul(p, x, y, z, 100);
    });
}
