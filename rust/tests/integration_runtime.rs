//! Cross-layer integration: the rust native bit-sliced simulator vs the
//! AOT-compiled JAX/Pallas kernels executed through PJRT.
//!
//! These tests require `make artifacts` to have been run; they are skipped
//! (with a message) when artifacts/ is absent so `cargo test` stays green
//! on a fresh checkout.

use prins::controller::Controller;
use prins::isa::{Field, Program};
use prins::micro;
use prins::rcam::PrinsArray;
use prins::runtime::{Golden, Runtime, XlaRcamBackend};
use prins::workloads::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime integration test: {e:#}");
            None
        }
    }
}

#[test]
fn xla_step_matches_native_simulator() {
    let Some(rt) = runtime() else { return };
    let mut xla = XlaRcamBackend::new(rt);
    let rows = 512usize; // a slice of the artifact's 64Ki rows
    let width = 32usize;
    let mut native = PrinsArray::single(xla.rows(), width);
    let mut rng = Rng::seed_from(11);
    for r in 0..rows {
        let v = rng.next_u32() as u64;
        native.load_row_bits(r, 0, 32, v);
        xla.load_row_bits(r, 0, 32, v);
    }
    for _ in 0..5 {
        let ncols = 1 + rng.below(4) as usize;
        let cpat: Vec<(u16, bool)> = (0..ncols)
            .map(|_| (rng.below(width as u64) as u16, rng.below(2) == 1))
            .collect();
        let wpat: Vec<(u16, bool)> = (0..ncols)
            .map(|_| (rng.below(width as u64) as u16, rng.below(2) == 1))
            .collect();
        // patterns may repeat a column; dedupe keeping first (both sides
        // must see identical patterns either way)
        let dedup = |p: &[(u16, bool)]| {
            let mut seen = std::collections::HashSet::new();
            p.iter()
                .filter(|(c, _)| seen.insert(*c))
                .copied()
                .collect::<Vec<_>>()
        };
        let cpat = dedup(&cpat);
        let wpat = dedup(&wpat);
        native.compare(&cpat);
        native.write(&wpat);
        let tags = xla.step(&cpat, &wpat).expect("xla step");
        let snap = native.tags_snapshot();
        for r in 0..rows {
            let xt = (tags[r / 32] >> (r % 32)) & 1 == 1;
            assert_eq!(snap.get(r), xt, "tag mismatch at row {r}");
        }
        for r in 0..rows {
            assert_eq!(
                native.fetch_row_bits(r, 0, 32),
                xla.fetch_row_bits(r, 0, 32),
                "state mismatch at row {r}"
            );
        }
    }
}

#[test]
fn xla_program_executor_runs_vec_add() {
    let Some(rt) = runtime() else { return };
    let mut xla = XlaRcamBackend::new(rt);
    let (a, b, s) = (Field::new(0, 16), Field::new(16, 16), Field::new(32, 17));
    let mut prog = Program::new();
    micro::vec_add(&mut prog, a, b, s, 60);
    let mut ctl = Controller::new(PrinsArray::single(1024, 64));
    let mut rng = Rng::seed_from(5);
    let mut cases = Vec::new();
    for r in 0..256 {
        let (av, bv) = (rng.below(1 << 16), rng.below(1 << 16));
        ctl.array.load_row_bits(r, 0, 16, av);
        ctl.array.load_row_bits(r, 16, 16, bv);
        xla.load_row_bits(r, 0, 16, av);
        xla.load_row_bits(r, 16, 16, bv);
        cases.push((av, bv));
    }
    ctl.execute(&prog);
    xla.run_program(&prog).expect("xla program");
    for (r, (av, bv)) in cases.iter().enumerate() {
        assert_eq!(xla.fetch_row_bits(r, 32, 17), av + bv, "row {r}");
        assert_eq!(
            xla.fetch_row_bits(r, 32, 17),
            ctl.array.fetch_row_bits(r, 32, 17)
        );
    }
}

#[test]
fn xla_compare_count_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut xla = XlaRcamBackend::new(rt);
    let mut native = PrinsArray::single(xla.rows(), 8);
    let mut rng = Rng::seed_from(21);
    for r in 0..2048 {
        let v = rng.below(256);
        native.load_row_bits(r, 0, 8, v);
        xla.load_row_bits(r, 0, 8, v);
    }
    let f = Field::new(0, 8);
    for key in [0u64, 17, 255] {
        let pat = f.pattern(key);
        native.compare(&pat);
        let expect = native.count_tags();
        let got = xla.compare_count(&pat).expect("compare_count");
        assert_eq!(got, expect, "key {key}");
    }
}

#[test]
fn golden_kernels_match_scalar_reference() {
    let Some(rt) = runtime() else { return };
    let mut g = Golden::new(rt);
    let mut rng = Rng::seed_from(31);
    // ED + DP on a non-artifact-sized input (forces padding/chunking)
    let (n, d) = (1000usize, 5usize);
    let x: Vec<f32> = (0..n * d).map(|_| rng.f32_range(-2.0, 2.0)).collect();
    let c: Vec<f32> = (0..d).map(|_| rng.f32_range(-2.0, 2.0)).collect();
    let ed = g.euclidean(&x, n, d, &c).expect("ed");
    let dp = g.dot_product(&x, n, d, &c).expect("dp");
    for i in 0..n {
        let mut e = 0f32;
        let mut p = 0f32;
        for j in 0..d {
            let diff = x[i * d + j] - c[j];
            e += diff * diff;
            p += x[i * d + j] * c[j];
        }
        assert!((ed[i] - e).abs() <= 1e-4 * e.abs().max(1.0), "ed[{i}]");
        assert!((dp[i] - p).abs() <= 1e-4 * p.abs().max(1.0), "dp[{i}]");
    }
    // histogram with padding correction
    let xs: Vec<u32> = (0..100_000).map(|_| rng.next_u32()).collect();
    let h = g.histogram(&xs).expect("hist");
    let mut expect = vec![0i32; 256];
    for &v in &xs {
        expect[(v >> 24) as usize] += 1;
    }
    assert_eq!(h, expect);
    assert_eq!(h.iter().map(|&v| v as i64).sum::<i64>(), xs.len() as i64);
    // spmv on a small random matrix
    let nb = 64usize;
    let nnz = 400usize;
    let rows: Vec<i32> = (0..nnz).map(|_| rng.below(nb as u64) as i32).collect();
    let cols: Vec<i32> = (0..nnz).map(|_| rng.below(nb as u64) as i32).collect();
    let vals: Vec<f32> = (0..nnz).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let xv: Vec<f32> = (0..nb).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let y = g.spmv(&rows, &cols, &vals, &xv).expect("spmv");
    let mut ye = vec![0f32; nb];
    for k in 0..nnz {
        ye[rows[k] as usize] += vals[k] * xv[cols[k] as usize];
    }
    for i in 0..nb {
        assert!((y[i] - ye[i]).abs() < 1e-4, "y[{i}]: {} vs {}", y[i], ye[i]);
    }
}
