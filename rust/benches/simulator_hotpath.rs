//! Simulator hot-path microbenchmarks (L3 perf target, DESIGN.md §6):
//! word-ops/second of the bit-sliced compare/write inner loops, the
//! microcode executor, and the chain field-shift, swept over the
//! parallel-backend worker count. These are the numbers the §Perf
//! optimization loop tracks; every run writes `BENCH_hotpath.json` at
//! the repository root so the perf trajectory is machine-readable.
//!
//! Flags (after `cargo bench --bench simulator_hotpath --`):
//!   --rows N          array rows (default 1<<20)
//!   --workers a,b,c   worker-count sweep (default 1,2,4,8; 1 = serial)
//!   --verify          assert threaded results/stats identical to serial
use prins::controller::Controller;
use prins::isa::{Field, Instr, Program};
use prins::metrics::bench::{
    arg_u64, time_it, workers_sweep_from_args, write_bench_json, BenchRecord,
};
use prins::micro;
use prins::rcam::{ExecBackend, PrinsArray};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows = arg_u64(&args, "--rows", 1 << 20) as usize;
    let sweep = workers_sweep_from_args(&args, &[1, 2, 4, 8]);
    let verify = args.iter().any(|a| a == "--verify");
    println!("rows = {rows}, workers sweep = {sweep:?}");

    let pat3: Vec<(u16, bool)> = vec![(0, true), (5, false), (9, true)];
    let wpat: Vec<(u16, bool)> = vec![(12, true), (13, false)];

    let mut records: Vec<BenchRecord> = Vec::new();
    let mut push = |records: &mut Vec<BenchRecord>, bench: &str, w: usize, ops: f64, wall: f64| {
        records.push(BenchRecord {
            bench: bench.into(),
            rows: rows as u64,
            workers: w as u64,
            ops_per_s: ops,
            wall_s: wall,
        });
    };

    for &w in &sweep {
        let be = ExecBackend::from_workers(w);
        println!("-- workers = {w} ({be:?}) --");

        let mut arr = PrinsArray::single(rows, 64).with_backend(be);
        let t = time_it(&format!("compare (3 cols) x100 [w={w}]"), 3, 10, || {
            for _ in 0..100 {
                arr.compare(&pat3);
            }
        });
        println!("{}", t.report());
        let per = (t.min().as_secs_f64() / 100.0).max(1e-12);
        let ops = rows as f64 * 3.0 / per;
        println!("  -> {ops:.2e} row-col ops/s");
        push(&mut records, "compare_3col", w, ops, t.min().as_secs_f64());

        // fused compare+write pass (3 compare cols + 2 write cols per row)
        let t = time_it(&format!("compare+write pass x100 [w={w}]"), 3, 10, || {
            for _ in 0..100 {
                arr.pass(&pat3, &wpat);
            }
        });
        println!("{}", t.report());
        let per = (t.min().as_secs_f64() / 100.0).max(1e-12);
        let ops = rows as f64 * 5.0 / per;
        println!("  -> {ops:.2e} row-col ops/s");
        push(&mut records, "pass_3c2w", w, ops, t.min().as_secs_f64());

        // full 16-bit add microprogram: one long data-parallel span, so
        // the whole program is a single pool dispatch per execute
        let (a, b) = (Field::new(0, 16), Field::new(16, 16));
        let mut prog = Program::new();
        micro::add_inplace(&mut prog, a, b, 60);
        let mut ctl = Controller::new(PrinsArray::single(rows, 64).with_backend(be));
        let t = time_it(&format!("16-bit vec add [w={w}]"), 1, 5, || {
            ctl.execute(&prog);
        });
        println!("{}", t.report());
        let passes = prog.n_passes() as f64;
        let rps = rows as f64 * passes / t.min().as_secs_f64().max(1e-12);
        println!("  -> {rps:.2e} row-passes/s");
        push(&mut records, "vec_add16", w, rps, t.min().as_secs_f64());

        // chain field shift (serializing op — backend-independent, kept
        // in the trajectory as the barrier-path baseline)
        let mut arr = PrinsArray::new(4, (rows / 4).max(1), 160).with_backend(be);
        let t = time_it(&format!("chain shift 48 cols x16 hops [w={w}]"), 1, 5, || {
            arr.shift_columns_to(0, 64, 48, 16);
        });
        println!("{}", t.report());
        let ops = arr.total_rows() as f64 * 48.0 * 16.0 / t.min().as_secs_f64().max(1e-12);
        push(&mut records, "chain_shift", w, ops, t.min().as_secs_f64());
    }

    // thread-scaling summary (speedup vs the first sweep entry)
    println!("\n== thread scaling (row-col ops/s, speedup vs w={}) ==", sweep[0]);
    for bench in ["compare_3col", "pass_3c2w", "vec_add16"] {
        let base = records
            .iter()
            .find(|r| r.bench == bench)
            .map(|r| r.ops_per_s)
            .unwrap_or(0.0);
        for r in records.iter().filter(|r| r.bench == bench) {
            println!(
                "{:<14} w={:<2} {:>10.3e} ops/s  ({:.2}x)",
                r.bench,
                r.workers,
                r.ops_per_s,
                if base > 0.0 { r.ops_per_s / base } else { 0.0 }
            );
        }
    }

    if verify {
        verify_equivalence(rows);
        println!("\nVERIFY OK: threaded backends bit-identical to serial");
    }

    match write_bench_json("hotpath", &records) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_hotpath.json: {e}");
            std::process::exit(1);
        }
    }
}

/// Equivalence gate for CI (`--verify`): a real microprogram plus
/// serializing instructions, run on serial and threaded backends over an
/// array whose rows do NOT divide evenly into stripes; storage, tags,
/// data buffers, cycles, and energy ledgers must match exactly.
fn verify_equivalence(rows: usize) {
    let rows = rows.min(1 << 16);
    let build = |be: ExecBackend| -> Controller {
        // odd per-module row count => uneven word stripes
        let mut c = Controller::new(PrinsArray::new(2, rows / 2 + 3, 64).with_backend(be));
        for r in 0..c.array.total_rows() {
            let v = (r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) & 0xFFFF_FFFF;
            c.array.load_row_bits(r, 0, 32, v);
        }
        c
    };
    let (a, b) = (Field::new(0, 16), Field::new(16, 16));
    let mut prog = Program::new();
    micro::add_inplace(&mut prog, a, b, 60);
    prog.push(Instr::ReduceCount);
    prog.push(Instr::ShiftTagsUp(5));
    prog.compare_field(Field::new(0, 4), 0xA);
    prog.push(Instr::ReduceField { col: 1 });
    prog.push(Instr::Read { base: 0, width: 16 });

    let mut s = build(ExecBackend::Serial);
    let out_s = s.execute_collect(&prog);
    for n in [2usize, 4, 8] {
        let mut t = build(ExecBackend::Threaded(n));
        let out_t = t.execute_collect(&prog);
        assert_eq!(out_s, out_t, "workers={n}: data buffer");
        assert_eq!(s.array.cycles, t.array.cycles, "workers={n}: cycles");
        assert_eq!(s.array.ledger(), t.array.ledger(), "workers={n}: ledger");
        assert_eq!(
            s.array.tags_snapshot().iter_ones().collect::<Vec<_>>(),
            t.array.tags_snapshot().iter_ones().collect::<Vec<_>>(),
            "workers={n}: tags"
        );
        for r in 0..s.array.total_rows() {
            assert_eq!(
                s.array.fetch_row_bits(r, 0, 64),
                t.array.fetch_row_bits(r, 0, 64),
                "workers={n}: row {r}"
            );
        }
        println!("verified workers={n} against serial ({} rows)", s.array.total_rows());
    }
}
