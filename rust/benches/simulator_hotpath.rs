//! Simulator hot-path microbenchmarks (L3 perf target, DESIGN.md §6):
//! word-ops/second of the bit-sliced compare/write inner loops, the
//! microcode executor, and the chain field-shift. These are the numbers
//! the §Perf optimization loop tracks.
use prins::controller::Controller;
use prins::isa::{Field, Program};
use prins::metrics::bench::time_it;
use prins::micro;
use prins::rcam::PrinsArray;

fn main() {
    let rows = 1 << 20; // 1M rows
    println!("rows = {rows}");

    let pat3: Vec<(u16, bool)> = vec![(0, true), (5, false), (9, true)];
    let wpat: Vec<(u16, bool)> = vec![(12, true), (13, false)];

    let mut arr = PrinsArray::single(rows, 64);
    let t = time_it("compare (3 cols) x100", 3, 10, || {
        for _ in 0..100 {
            arr.compare(&pat3);
        }
    });
    println!("{}", t.report());
    let per = t.min().as_secs_f64() / 100.0;
    println!(
        "  -> {:.2e} row-col ops/s",
        (rows as f64 * 3.0) / per
    );

    let t = time_it("compare+write pass x100", 3, 10, || {
        for _ in 0..100 {
            arr.compare(&pat3);
            arr.write(&wpat);
        }
    });
    println!("{}", t.report());

    // full 16-bit add microprogram over 1M rows
    let (a, b) = (Field::new(0, 16), Field::new(16, 16));
    let mut prog = Program::new();
    micro::add_inplace(&mut prog, a, b, 60);
    let mut ctl = Controller::new(PrinsArray::single(rows, 64));
    let t = time_it("16-bit vec add (1M rows)", 1, 5, || {
        ctl.execute(&prog);
    });
    println!("{}", t.report());
    let passes = prog.n_passes() as f64;
    println!(
        "  -> {:.2e} row-passes/s",
        rows as f64 * passes / t.min().as_secs_f64()
    );

    // chain shift
    let mut arr = PrinsArray::new(4, rows / 4, 160);
    let t = time_it("chain shift 48 cols x16 hops", 1, 5, || {
        arr.shift_columns_to(0, 64, 48, 16);
    });
    println!("{}", t.report());
}
