//! Regenerates paper Fig. 15: the roofline of KNL behind a 10 GB/s storage
//! appliance vs a 4 TB PRINS whose compute never leaves the storage
//! arrays. Run: `cargo bench --bench fig15_roofline`. The figure is
//! analytical (no array simulation), so `--workers` only tags the JSON
//! record for trajectory uniformity.
use prins::metrics::bench::{backend_from_args, write_bench_json, BenchRecord};
use prins::model::figures;
use prins::model::roofline;
use prins::rcam::DeviceModel;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend = backend_from_args(&args);
    let t0 = std::time::Instant::now();
    let t = figures::fig15();
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", t.render());
    let dev = DeviceModel::default();
    let bw = roofline::prins_internal_bandwidth_gb_s(1_000_000_000_000, dev.freq_hz);
    println!("PRINS internal bandwidth (bit-column -> tags, 1T rows): {bw:.2e} GB/s");
    println!("vs external appliance 10 GB/s and NVDIMM 24 GB/s.");
    let rec = BenchRecord {
        bench: "fig15".into(),
        rows: 0,
        workers: backend.workers() as u64,
        ops_per_s: 0.0,
        wall_s: wall,
    };
    if let Ok(p) = write_bench_json("fig15", &[rec]) {
        println!("wrote {}", p.display());
    }
}
