//! Regenerates paper Fig. 15: the roofline of KNL behind a 10 GB/s storage
//! appliance vs a 4 TB PRINS whose compute never leaves the storage
//! arrays. Run: `cargo bench --bench fig15_roofline`.
use prins::model::figures;
use prins::model::roofline;
use prins::rcam::DeviceModel;

fn main() {
    let t = figures::fig15();
    println!("{}", t.render());
    let dev = DeviceModel::default();
    let bw = roofline::prins_internal_bandwidth_gb_s(1_000_000_000_000, dev.freq_hz);
    println!("PRINS internal bandwidth (bit-column -> tags, 1T rows): {bw:.2e} GB/s");
    println!("vs external appliance 10 GB/s and NVDIMM 24 GB/s.");
}
