//! Ablations over the design choices DESIGN.md calls out:
//!  1. paper-faithful 8-pass adder vs optimized 4-pass in-place adder
//!  2. SpMV chain-tree reduction vs the literal per-row reduction sweep
//!  3. XLA/Pallas backend vs native bit-sliced backend (wall-clock, same
//!     semantics — measures the simulator, not the device)
use prins::controller::Controller;
use prins::isa::{Field, Program};
use prins::metrics::bench::{backend_from_args, time_it};
use prins::micro;
use prins::rcam::PrinsArray;
use prins::storage::StorageManager;
use prins::workloads::{synth_csr, Rng};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend = backend_from_args(&args);
    println!("simulator backend: {backend:?} (--workers N; device results invariant)\n");

    // --- 1: adder microcode cost (device cycles) ---
    println!("== ablation 1: adder microcode (device cycles per 16-bit add) ==");
    let (a, b, s) = (Field::new(0, 16), Field::new(16, 16), Field::new(32, 17));
    let mut p8 = Program::new();
    micro::vec_add(&mut p8, a, b, s, 60);
    let mut p4 = Program::new();
    micro::add_inplace(&mut p4, a, b, 60);
    println!("paper 8-pass form : {:>5} passes {:>6} cycles", p8.n_passes(), p8.cycle_estimate());
    println!("optimized 4-pass  : {:>5} passes {:>6} cycles", p4.n_passes(), p4.cycle_estimate());
    println!(
        "speedup: {:.2}x\n",
        p8.cycle_estimate() as f64 / p4.cycle_estimate() as f64
    );

    // --- 2: SpMV reduce engines (device cycles) ---
    println!("== ablation 2: SpMV reduction engine (device cycles) ==");
    use prins::algorithms::spmv::{ReduceEngine, SpmvKernel};
    let a = synth_csr(1024, 8192, 77);
    let mut rng = Rng::seed_from(78);
    let x: Vec<f32> = (0..a.n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    for (name, engine) in [
        ("chain-tree ([79])", ReduceEngine::ChainTree),
        ("serial sweep (Fig.10)", ReduceEngine::SerialTree),
    ] {
        let mut array = PrinsArray::single(a.nnz(), 256).with_backend(backend);
        let mut sm = StorageManager::new(a.nnz());
        let kern = SpmvKernel::load(&mut sm, &mut array, &a);
        let mut ctl = Controller::new(array);
        let res = kern.run(&mut ctl, &x, engine);
        println!(
            "{name:<22}: reduce {:>9} cycles (total {:>9})",
            res.reduce_cycles, res.stats.cycles
        );
    }
    println!();

    // --- 3: native vs XLA backend (simulator wall-clock) ---
    println!("== ablation 3: associative-step backend (simulator wall-clock) ==");
    let pat: Vec<(u16, bool)> = vec![(0, true), (5, false), (9, true)];
    let wpat: Vec<(u16, bool)> = vec![(12, true)];
    // clone per iteration: fresh storage state, shared worker pool
    let proto = PrinsArray::single(65536, 32).with_backend(backend);
    let t_native = time_it("native bit-sliced step (64Ki rows)", 2, 10, || {
        let mut arr = proto.clone();
        for _ in 0..16 {
            arr.compare(&pat);
            arr.write(&wpat);
        }
        arr.cycles
    });
    println!("{}", t_native.report());
    match prins::runtime::Runtime::open("artifacts") {
        Ok(rt) => {
            let mut xla = prins::runtime::XlaRcamBackend::new(rt);
            // warm the compile cache before timing
            let _ = xla.step(&pat, &wpat);
            let t_xla = time_it("XLA/Pallas step (64Ki rows)", 1, 10, || {
                for _ in 0..16 {
                    let _ = xla.step(&pat, &wpat).unwrap();
                }
            });
            println!("{}", t_xla.report());
            println!(
                "native/XLA wall-clock ratio: {:.1}x (XLA pays per-call literal transfers;\nuse the scan-composed program executor for amortization)",
                t_xla.mean().as_secs_f64() / t_native.mean().as_secs_f64()
            );
        }
        Err(e) => println!("XLA backend skipped: {e:#}"),
    }
}
