//! Multiplexed-serving throughput bench (DESIGN.md §Serving): drive a
//! live [`prins::host::server::Server`] over TCP with a sweep of
//! concurrent clients × pipeline depths, once per admission mode —
//!
//!   1. **exclusive**: shared-read admission off; every request is
//!      serialized per connection through the `&mut` resident path (the
//!      baseline),
//!   2. **shared**: write-free resident queries admit as concurrent
//!      readers over the same resident rows,
//!
//! and write one record per (clients, pipeline, mode) cell to
//! `BENCH_throughput.json` at the repository root. Every client loads
//! its own resident hist dataset, then fires its queries with the
//! requested pipeline window, asserting each reply is byte-identical to
//! the connection's first — concurrency must never change a reply bit.
//! The CI smoke gate checks qps(many clients) > qps(1 client) in shared
//! mode and that both servers shut down cleanly.
//!
//! Flags (after `cargo bench --bench throughput -- ...`):
//!   --rows N          resident dataset rows per client (default 2000)
//!   --queries Q       queries per client (default 32)
//!   --clients a,b,c   concurrent-connection sweep (default 1,4,16)
//!   --pipeline a,b,c  in-flight request lines per client (default 1,8)

use prins::host::server::{ServeOptions, Server};
use prins::metrics::bench::{arg_u64, arg_value, write_throughput_json, ThroughputRecord};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Instant;

/// Comma-separated `usize` sweep behind a flag, with a default.
fn usize_sweep(args: &[String], name: &str, default: &[usize]) -> Vec<usize> {
    match arg_value(args, name) {
        Some(list) => {
            let v: Vec<usize> = list
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .filter(|&n: &usize| n > 0)
                .collect();
            if v.is_empty() {
                default.to_vec()
            } else {
                v
            }
        }
        None => default.to_vec(),
    }
}

/// One measured cell: `clients` connections, each loading a resident
/// hist dataset and firing `queries` pipelined `HIST <id>` requests with
/// `pipeline` lines in flight. Returns (total queries, wall seconds of
/// the query phase). Panics on any dropped connection, non-OK reply, or
/// reply that differs from the connection's first — so a passing bench
/// run is itself a correctness check.
fn run_cell(
    addr: SocketAddr,
    clients: usize,
    pipeline: usize,
    queries: usize,
    rows: usize,
) -> (u64, f64) {
    let barrier = Arc::new(Barrier::new(clients + 1));
    let mut handles = Vec::with_capacity(clients);
    for _ in 0..clients {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).expect("connect failed");
            conn.set_nodelay(true).ok();
            let mut reader = BufReader::new(conn.try_clone().expect("clone failed"));
            let mut line = String::new();
            writeln!(conn, "LOAD HIST {rows} 7").expect("load write failed");
            reader.read_line(&mut line).expect("load reply dropped");
            assert!(line.starts_with("OK id=1 kind=hist"), "{line}");
            barrier.wait(); // every client loaded: start the clock
            let window = pipeline.min(queries);
            let mut sent = 0usize;
            for _ in 0..window {
                writeln!(conn, "HIST 1").expect("query write failed");
                sent += 1;
            }
            let mut reference: Option<String> = None;
            for _ in 0..queries {
                line.clear();
                reader.read_line(&mut line).expect("query reply dropped");
                assert!(line.starts_with("OK"), "{line}");
                match &reference {
                    Some(r) => assert_eq!(
                        r.as_str(),
                        line.trim(),
                        "reply drift under concurrency"
                    ),
                    None => reference = Some(line.trim().to_string()),
                }
                if sent < queries {
                    writeln!(conn, "HIST 1").expect("query write failed");
                    sent += 1;
                }
            }
            line.clear();
            writeln!(conn, "QUIT").expect("quit write failed");
            reader.read_line(&mut line).expect("bye dropped");
            assert_eq!(line.trim(), "BYE");
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let wall = t0.elapsed().as_secs_f64();
    ((clients * queries) as u64, wall)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows = arg_u64(&args, "--rows", 2000) as usize;
    let queries = arg_u64(&args, "--queries", 32) as usize;
    let clients_sweep = usize_sweep(&args, "--clients", &[1, 4, 16]);
    let pipeline_sweep = usize_sweep(&args, "--pipeline", &[1, 8]);
    assert!(queries > 0, "--queries must be positive");
    println!(
        "rows = {rows}, queries/client = {queries}, clients sweep = {clients_sweep:?}, \
         pipeline sweep = {pipeline_sweep:?}"
    );

    let mut records: Vec<ThroughputRecord> = Vec::new();
    for (mode, shared) in [("exclusive", false), ("shared", true)] {
        let opts = ServeOptions {
            shared_read: shared,
            ..ServeOptions::default()
        };
        let server = Server::spawn_opts("127.0.0.1:0", opts).expect("server spawn failed");
        for &clients in &clients_sweep {
            for &pipeline in &pipeline_sweep {
                let (nq, wall) = run_cell(server.addr, clients, pipeline, queries, rows);
                let qps = nq as f64 / wall;
                println!(
                    "hist   mode={mode:<9} clients={clients:<3} pipeline={pipeline:<3} \
                     queries={nq:<6} qps={qps:>10.1} wall={wall:.3}s"
                );
                records.push(ThroughputRecord {
                    bench: "hist".into(),
                    clients: clients as u64,
                    pipeline: pipeline as u64,
                    mode: mode.into(),
                    queries: nq,
                    qps,
                    wall_s: wall,
                });
            }
        }
        // clean shutdown per mode — the CI smoke gate relies on this
        // returning (a hung mux or worker would wedge the bench here)
        server.shutdown();
        println!("{mode} server shut down cleanly");
    }

    match write_throughput_json("throughput", &records) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_throughput.json: {e}");
            std::process::exit(1);
        }
    }
}
