//! Multiplexed-serving throughput bench (DESIGN.md §Serving): drive a
//! live [`prins::host::server::Server`] over TCP with a sweep of
//! concurrent clients × pipeline depths, once per admission mode —
//!
//!   1. **exclusive**: shared-read admission off; every request is
//!      serialized through the resident slot (the baseline),
//!   2. **shared**: write-free resident queries admit as concurrent
//!      readers, each client over its own resident hist dataset,
//!   3. **cross_exclusive** / **cross_session**: every client hammers
//!      **one** search dataset loaded once by a setup connection — the
//!      cross-session shape (docs/PROTOCOL.md §Sharing). `cross_session`
//!      adds shared admission plus the cross-connection coalescer, and
//!      its records carry `coalesced_per_op_cycles` scraped from the
//!      dataset's `STATS` counters,
//!
//! and write one record per (clients, pipeline, mode) cell to
//! `BENCH_throughput.json` at the repository root. Every reply is
//! asserted byte-identical to the connection's first — concurrency and
//! coalescing must never change a reply bit — and the cross_session
//! mode ends with a deterministic one-packet burst proving the
//! coalescer's amortized per-query cycles beat the solo-query cost.
//! The CI smoke gate checks qps(many clients) > qps(1 client) in shared
//! mode, qps(cross_session) > qps(cross_exclusive) at the widest cell,
//! and that all four servers shut down cleanly.
//!
//! Flags (after `cargo bench --bench throughput -- ...`):
//!   --rows N          resident dataset rows (default 2000)
//!   --queries Q       queries per client (default 32)
//!   --clients a,b,c   concurrent-connection sweep (default 1,4,16)
//!   --pipeline a,b,c  in-flight request lines per client (default 1,8)

use prins::host::server::{ServeOptions, Server};
use prins::metrics::bench::{arg_u64, arg_value, write_throughput_json, ThroughputRecord};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Instant;

/// Comma-separated `usize` sweep behind a flag, with a default.
fn usize_sweep(args: &[String], name: &str, default: &[usize]) -> Vec<usize> {
    match arg_value(args, name) {
        Some(list) => {
            let v: Vec<usize> = list
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .filter(|&n: &usize| n > 0)
                .collect();
            if v.is_empty() {
                default.to_vec()
            } else {
                v
            }
        }
        None => default.to_vec(),
    }
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).expect("connect failed");
    conn.set_nodelay(true).ok();
    let reader = BufReader::new(conn.try_clone().expect("clone failed"));
    (conn, reader)
}

fn ask(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> String {
    writeln!(conn, "{req}").expect("write failed");
    let mut line = String::new();
    reader.read_line(&mut line).expect("reply dropped");
    line.trim().to_string()
}

/// `key=`-prefixed numeric field of a reply.
fn field(reply: &str, key: &str) -> u64 {
    reply
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix(key))
        .unwrap_or_else(|| panic!("no {key} in {reply}"))
        .parse()
        .unwrap_or_else(|e| panic!("bad {key} in {reply}: {e}"))
}

/// Fire `queries` pipelined lines of `query` and assert every reply is
/// byte-identical to the connection's first.
fn drive_queries(
    conn: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    query: &str,
    pipeline: usize,
    queries: usize,
) {
    let window = pipeline.min(queries);
    let mut sent = 0usize;
    for _ in 0..window {
        writeln!(conn, "{query}").expect("query write failed");
        sent += 1;
    }
    let mut line = String::new();
    let mut reference: Option<String> = None;
    for _ in 0..queries {
        line.clear();
        reader.read_line(&mut line).expect("query reply dropped");
        assert!(line.starts_with("OK"), "{line}");
        match &reference {
            Some(r) => assert_eq!(r.as_str(), line.trim(), "reply drift under concurrency"),
            None => reference = Some(line.trim().to_string()),
        }
        if sent < queries {
            writeln!(conn, "{query}").expect("query write failed");
            sent += 1;
        }
    }
}

/// Per-client-dataset cell: `clients` connections each load their own
/// resident hist dataset (ids are global, so each parses its own from
/// the `LOAD` reply) and fire `queries` pipelined `HIST <id>` requests,
/// dropping the dataset before `QUIT` so cells never pressure the
/// table cap. Returns (total queries, wall seconds of the query phase).
fn run_cell(
    addr: SocketAddr,
    clients: usize,
    pipeline: usize,
    queries: usize,
    rows: usize,
) -> (u64, f64) {
    let barrier = Arc::new(Barrier::new(clients + 1));
    let mut handles = Vec::with_capacity(clients);
    for _ in 0..clients {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            let loaded = ask(&mut conn, &mut reader, &format!("LOAD HIST {rows} 7"));
            assert!(loaded.starts_with("OK id="), "{loaded}");
            let id = field(&loaded, "id=");
            barrier.wait(); // every client loaded: start the clock
            drive_queries(&mut conn, &mut reader, &format!("HIST {id}"), pipeline, queries);
            assert_eq!(ask(&mut conn, &mut reader, &format!("DROP {id}")), format!("OK dropped={id}"));
            assert_eq!(ask(&mut conn, &mut reader, "QUIT"), "BYE");
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let wall = t0.elapsed().as_secs_f64();
    ((clients * queries) as u64, wall)
}

/// Cross-session cell: `clients` connections all fire pipelined
/// single-operand `SEARCH` queries at the one pre-loaded dataset (id 1
/// on a fresh server). Returns (total queries, wall seconds).
fn run_cross_cell(
    addr: SocketAddr,
    clients: usize,
    pipeline: usize,
    queries: usize,
) -> (u64, f64) {
    let barrier = Arc::new(Barrier::new(clients + 1));
    let mut handles = Vec::with_capacity(clients);
    for _ in 0..clients {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            barrier.wait();
            drive_queries(&mut conn, &mut reader, "SEARCH 1 100 5000", pipeline, queries);
            assert_eq!(ask(&mut conn, &mut reader, "QUIT"), "BYE");
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let wall = t0.elapsed().as_secs_f64();
    ((clients * queries) as u64, wall)
}

/// (coal_batches, coal_members, coal_cycles) of dataset 1 via `STATS`.
fn coal_counters(addr: SocketAddr) -> (u64, u64, u64) {
    let (mut conn, mut reader) = connect(addr);
    let stats = ask(&mut conn, &mut reader, "STATS 1");
    assert!(stats.starts_with("OK dataset=1"), "{stats}");
    let out = (
        field(&stats, "coal_batches="),
        field(&stats, "coal_members="),
        field(&stats, "coal_cycles="),
    );
    ask(&mut conn, &mut reader, "QUIT");
    out
}

/// Deterministic coalescing probe: one connection writes `burst`
/// identical `SEARCH` lines in a single packet, so the mux sees them
/// pending together and must merge the front run. Packet boundaries are
/// not guaranteed end to end, so retry a few times; every reply must
/// equal the solo `reference` on every attempt, merged or not. Returns
/// the amortized device cycles per coalesced query.
fn ensure_coalesced(addr: SocketAddr, burst: usize, reference: &str) -> f64 {
    let (b0, m0, c0) = coal_counters(addr);
    for attempt in 0..20 {
        let (mut conn, mut reader) = connect(addr);
        let packet: String = std::iter::repeat("SEARCH 1 100 5000\n").take(burst).collect();
        conn.write_all(packet.as_bytes()).expect("burst write failed");
        let mut line = String::new();
        for _ in 0..burst {
            line.clear();
            reader.read_line(&mut line).expect("burst reply dropped");
            assert_eq!(line.trim(), reference, "coalesced reply diverged from solo");
        }
        ask(&mut conn, &mut reader, "QUIT");
        let (b1, m1, c1) = coal_counters(addr);
        if b1 > b0 {
            println!(
                "coalescing probe: attempt {attempt}, {} batches / {} members merged",
                b1 - b0,
                m1 - m0
            );
            return (c1 - c0) as f64 / (m1 - m0) as f64;
        }
    }
    panic!("coalescing probe: no burst merged in 20 attempts");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows = arg_u64(&args, "--rows", 2000) as usize;
    let queries = arg_u64(&args, "--queries", 32) as usize;
    let clients_sweep = usize_sweep(&args, "--clients", &[1, 4, 16]);
    let pipeline_sweep = usize_sweep(&args, "--pipeline", &[1, 8]);
    assert!(queries > 0, "--queries must be positive");
    println!(
        "rows = {rows}, queries/client = {queries}, clients sweep = {clients_sweep:?}, \
         pipeline sweep = {pipeline_sweep:?}"
    );

    let mut records: Vec<ThroughputRecord> = Vec::new();

    // per-client-dataset sweep (the original shape)
    for (mode, shared) in [("exclusive", false), ("shared", true)] {
        let opts = ServeOptions {
            shared_read: shared,
            ..ServeOptions::default()
        };
        let server = Server::spawn_opts("127.0.0.1:0", opts).expect("server spawn failed");
        for &clients in &clients_sweep {
            for &pipeline in &pipeline_sweep {
                let (nq, wall) = run_cell(server.addr, clients, pipeline, queries, rows);
                let qps = nq as f64 / wall;
                println!(
                    "hist   mode={mode:<15} clients={clients:<3} pipeline={pipeline:<3} \
                     queries={nq:<6} qps={qps:>10.1} wall={wall:.3}s"
                );
                records.push(ThroughputRecord {
                    bench: "hist".into(),
                    clients: clients as u64,
                    pipeline: pipeline as u64,
                    mode: mode.into(),
                    queries: nq,
                    qps,
                    wall_s: wall,
                    coalesced_per_op_cycles: 0.0,
                });
            }
        }
        // clean shutdown per mode — the CI smoke gate relies on this
        // returning (a hung mux or worker would wedge the bench here)
        server.shutdown();
        println!("{mode} server shut down cleanly");
    }

    // cross-session sweep: one dataset, loaded once, hammered by all
    for (mode, shared) in [("cross_exclusive", false), ("cross_session", true)] {
        let opts = ServeOptions {
            shared_read: shared,
            ..ServeOptions::default()
        };
        let server = Server::spawn_opts("127.0.0.1:0", opts).expect("server spawn failed");
        let (mut setup, mut setup_r) = connect(server.addr);
        let loaded = ask(&mut setup, &mut setup_r, &format!("LOAD SEARCH {rows} 9"));
        assert!(loaded.starts_with("OK id=1 "), "{loaded}");
        let solo = ask(&mut setup, &mut setup_r, "SEARCH 1 100 5000");
        let solo_cycles = field(&solo, "cycles=");
        for &clients in &clients_sweep {
            for &pipeline in &pipeline_sweep {
                let (_, m0, c0) = coal_counters(server.addr);
                let (nq, wall) = run_cross_cell(server.addr, clients, pipeline, queries);
                let (_, m1, c1) = coal_counters(server.addr);
                let qps = nq as f64 / wall;
                let coalesced_per_op_cycles = if m1 > m0 {
                    (c1 - c0) as f64 / (m1 - m0) as f64
                } else {
                    0.0
                };
                println!(
                    "search mode={mode:<15} clients={clients:<3} pipeline={pipeline:<3} \
                     queries={nq:<6} qps={qps:>10.1} wall={wall:.3}s \
                     coalesced_per_op_cycles={coalesced_per_op_cycles:.1}"
                );
                records.push(ThroughputRecord {
                    bench: "search".into(),
                    clients: clients as u64,
                    pipeline: pipeline as u64,
                    mode: mode.into(),
                    queries: nq,
                    qps,
                    wall_s: wall,
                    coalesced_per_op_cycles,
                });
            }
        }
        if shared {
            // the amortization gate: a merged burst must cost fewer
            // device cycles per query than the solo dispatch it replaces
            let per_op = ensure_coalesced(server.addr, 8, &solo);
            assert!(
                per_op < solo_cycles as f64,
                "coalesced per-op cycles {per_op:.1} did not beat solo {solo_cycles}"
            );
            println!(
                "coalesced_per_op_cycles={per_op:.1} < solo_query_cycles={solo_cycles}"
            );
        }
        assert_eq!(ask(&mut setup, &mut setup_r, "DROP 1"), "OK dropped=1");
        assert_eq!(ask(&mut setup, &mut setup_r, "QUIT"), "BYE");
        server.shutdown();
        println!("{mode} server shut down cleanly");
    }

    match write_throughput_json("throughput", &records) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_throughput.json: {e}");
            std::process::exit(1);
        }
    }
}
