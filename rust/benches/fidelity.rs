//! BER → accuracy fidelity bench (DESIGN.md §Reliability): for every
//! registered kernel and every bit-error rate in the sweep, run the
//! same seeded workload three ways —
//!
//!   1. **ideal**: no fault layer; the reference bits per query,
//!   2. **raw**: faults on, scrub/retry recovery *off* — the
//!      single-attempt accuracy floor (`exact_rate`),
//!   3. **recovered**: faults on, recovery *on* — scrubbed accuracy
//!      (`recovered_rate`) plus the repair counters and the recovery
//!      overhead charged to the cycle ledger,
//!
//! and write one record per (kernel, BER) point to
//! `BENCH_fidelity.json` at the repository root. Fault draws use
//! common random numbers across BERs (a cell that flips at BER b also
//! flips at every BER > b), so `exact_rate` is monotone non-increasing
//! in BER by construction — the CI smoke gate asserts exactly that.
//!
//! Flags (after `cargo bench --bench fidelity -- ...`):
//!   --rows N          dataset rows (default 256; dense workloads cap
//!                     at 128 rows — printed when the cap applies)
//!   --dims D          vector dims for dense kernels (default 2)
//!   --queries Q       queries per point (default 4)
//!   --ber a,b,c       BER sweep (default 0,0.0005,0.005)
//!   --fault-seed S    fault-stream seed (default 7)
//!   --stuck N         random stuck-at cells per shard array (default 0)

use prins::host::rack::PrinsRack;
use prins::metrics::bench::{
    arg_u64, ber_sweep_from_args, write_fidelity_json, FidelityRecord,
};
use prins::reliability::FaultModel;
use std::time::Instant;

const SEED: u64 = 7;
const DENSE_CAP: usize = 128;

/// Mean per-element relative error of `got` vs the ideal `idl` bits,
/// each element capped at 1.0 (a completely wrong element costs 1.0, so
/// the mean stays in [0, 1] and one garbage word cannot swamp the run).
fn rel_err(bits_f32: bool, got: &[u64], idl: &[u64]) -> f64 {
    if got.len() != idl.len() || idl.is_empty() {
        return 1.0;
    }
    let mut sum = 0.0;
    for (&g, &r) in got.iter().zip(idl) {
        let e = if bits_f32 {
            let g = f32::from_bits(g as u32) as f64;
            let r = f32::from_bits(r as u32) as f64;
            (g - r).abs() / r.abs().max(1.0)
        } else {
            g.abs_diff(r) as f64 / (r as f64).max(1.0)
        };
        sum += if e.is_nan() { 1.0 } else { e.min(1.0) };
    }
    sum / idl.len() as f64
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows = arg_u64(&args, "--rows", 256) as usize;
    let dims = arg_u64(&args, "--dims", 2) as usize;
    let queries = arg_u64(&args, "--queries", 4) as usize;
    let bers = ber_sweep_from_args(&args, &[0.0, 5e-4, 5e-3]);
    let fault_seed = arg_u64(&args, "--fault-seed", SEED);
    let stuck = arg_u64(&args, "--stuck", 0) as usize;
    assert!(queries > 0, "--queries must be positive");

    if rows > DENSE_CAP {
        println!("note: dense kernels capped at {DENSE_CAP} rows (compare-only kernels use {rows})");
    }
    println!(
        "rows = {rows}, dims = {dims}, queries = {queries}, ber sweep = {bers:?}, \
         fault seed = {fault_seed}, stuck = {stuck}"
    );

    let ideal_rack = PrinsRack::new(1);
    let mut records: Vec<FidelityRecord> = Vec::new();
    for entry in prins::algorithms::kernel::registry() {
        let nrows = if entry.dense { rows.min(DENSE_CAP) } else { rows };

        // ideal reference bits, one result per query
        let t0 = Instant::now();
        let mut res = (entry.synth_load)(&ideal_rack, nrows, dims, SEED);
        let ideal: Vec<Vec<u64>> = (0..queries)
            .map(|q| res.query_seeded(q, SEED).bits)
            .collect();

        for &ber in &bers {
            let t1 = Instant::now();
            let model = FaultModel::uniform(ber, fault_seed).with_random_stuck(stuck);

            // raw: single attempt, no scrub — the accuracy floor
            let raw_rack = PrinsRack::new(1)
                .with_fault(model.clone().with_recovery(false))
                .expect("bench fault model rejected");
            let mut raw = (entry.synth_load)(&raw_rack, nrows, dims, SEED);
            let exact = (0..queries)
                .filter(|&q| raw.query_seeded(q, SEED).bits == ideal[q])
                .count();

            // recovered: scrub/retry on, overhead charged to the ledger
            let rec_rack = PrinsRack::new(1)
                .with_fault(model)
                .expect("bench fault model rejected");
            let mut rec = (entry.synth_load)(&rec_rack, nrows, dims, SEED);
            let (mut recovered, mut err_sum) = (0usize, 0.0f64);
            let (mut injected, mut detected, mut repaired, mut residual) = (0u64, 0u64, 0u64, 0u64);
            let (mut retries, mut overhead) = (0u64, 0u64);
            for q in 0..queries {
                let out = rec.query_seeded(q, SEED);
                if out.bits == ideal[q] {
                    recovered += 1;
                }
                err_sum += rel_err(entry.bits_f32, &out.bits, &ideal[q]);
                let f = out.fidelity.expect("fault-layer query returned no fidelity");
                injected += f.injected;
                detected += f.detected;
                repaired += f.repaired;
                residual += f.residual;
                retries += f.retries;
                overhead += f.overhead_cycles;
            }

            let wall = if ber == bers[0] {
                t0.elapsed().as_secs_f64()
            } else {
                t1.elapsed().as_secs_f64()
            };
            let exact_rate = exact as f64 / queries as f64;
            let recovered_rate = recovered as f64 / queries as f64;
            let mean_rel_err = err_sum / queries as f64;
            println!(
                "{:<6} ber={ber:<8.1e} exact={exact_rate:.2} recovered={recovered_rate:.2} \
                 rel_err={mean_rel_err:.2e} injected={injected:<6} detected={detected:<5} \
                 repaired={repaired:<5} residual={residual:<4} retries={retries:<3} \
                 overhead={overhead} cycles",
                entry.name
            );
            records.push(FidelityRecord {
                bench: entry.name.into(),
                rows: nrows as u64,
                queries: queries as u64,
                ber,
                exact_rate,
                recovered_rate,
                mean_rel_err,
                injected,
                detected,
                repaired,
                residual,
                retries,
                overhead_cycles: overhead,
                wall_s: wall,
            });
        }
    }

    match write_fidelity_json("fidelity", &records) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_fidelity.json: {e}");
            std::process::exit(1);
        }
    }
}
