//! Regenerates paper Fig. 12: ED / DP / Histogram performance normalized
//! to a bandwidth-limited external-storage architecture (10 GB/s appliance
//! and 24 GB/s NVDIMM), for 1M / 10M / 100M elements, plus the §6
//! GFLOPS/W numbers. Run: `cargo bench --bench fig12_dense`
//! (`-- --workers N` selects the simulator backend; results are
//! backend-invariant, only wall-clock changes).
use prins::metrics::bench::{backend_from_args, write_bench_json, BenchRecord};
use prins::model::figures;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend = backend_from_args(&args);
    let sim_rows = 1024usize;
    let t0 = std::time::Instant::now();
    let t = figures::fig12_on(figures::DIMS, sim_rows, backend);
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", t.render());
    println!("paper shape: ED/DP/Hist normalized speedup grows linearly in N,");
    println!("reaching 3-4 orders of magnitude at 100M; efficiency ~2-4 GFLOPS/W.");
    println!("(simulated in {wall:.3}s, backend {backend:?})");
    let rec = BenchRecord {
        bench: "fig12".into(),
        rows: sim_rows as u64,
        workers: backend.workers() as u64,
        ops_per_s: sim_rows as f64 / wall,
        wall_s: wall,
    };
    if let Ok(p) = write_bench_json("fig12", &[rec]) {
        println!("wrote {}", p.display());
    }
}
