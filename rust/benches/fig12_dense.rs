//! Regenerates paper Fig. 12: ED / DP / Histogram performance normalized
//! to a bandwidth-limited external-storage architecture (10 GB/s appliance
//! and 24 GB/s NVDIMM), for 1M / 10M / 100M elements, plus the §6
//! GFLOPS/W numbers. Run: `cargo bench --bench fig12_dense`.
use prins::model::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let t = figures::fig12(figures::DIMS, 1024);
    println!("{}", t.render());
    println!("paper shape: ED/DP/Hist normalized speedup grows linearly in N,");
    println!("reaching 3-4 orders of magnitude at 100M; efficiency ~2-4 GFLOPS/W.");
    println!("(simulated in {:?})", t0.elapsed());
}
