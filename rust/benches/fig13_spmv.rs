//! Regenerates paper Fig. 13: SpMV normalized performance (a) and power
//! efficiency (b) over the 18 UFL matrices (density-matched synthetics),
//! ordered by increasing density. Run: `cargo bench --bench fig13_spmv`
//! (`-- --workers N` selects the simulator backend; results are
//! backend-invariant, only wall-clock changes).
use prins::metrics::bench::{backend_from_args, write_bench_json, BenchRecord};
use prins::model::figures;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend = backend_from_args(&args);
    let sim_n = 1500usize;
    let t0 = std::time::Instant::now();
    let t = figures::fig13_on(sim_n, backend);
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", t.render());
    println!("paper shape: normalized performance grows with matrix density,");
    println!("exceeding two orders of magnitude at the dense end (nd24k).");
    println!("(simulated in {wall:.3}s, backend {backend:?})");
    let rec = BenchRecord {
        bench: "fig13".into(),
        rows: sim_n as u64,
        workers: backend.workers() as u64,
        ops_per_s: sim_n as f64 / wall,
        wall_s: wall,
    };
    if let Ok(p) = write_bench_json("fig13", &[rec]) {
        println!("wrote {}", p.display());
    }
}
