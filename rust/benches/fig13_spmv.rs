//! Regenerates paper Fig. 13: SpMV normalized performance (a) and power
//! efficiency (b) over the 18 UFL matrices (density-matched synthetics),
//! ordered by increasing density. Run: `cargo bench --bench fig13_spmv`.
use prins::model::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let t = figures::fig13(1500);
    println!("{}", t.render());
    println!("paper shape: normalized performance grows with matrix density,");
    println!("exceeding two orders of magnitude at the dense end (nd24k).");
    println!("(simulated in {:?})", t0.elapsed());
}
