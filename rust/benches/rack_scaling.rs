//! Rack shard-count scaling bench (DESIGN.md §Sharding): run the four
//! sharded workloads (hist / dp / ed / spmv) over a shard-count sweep and
//! write the modeled rack figures to `BENCH_rack.json` at the repository
//! root — the scaling curves the README's "Run a rack" table is fed from.
//!
//! Flags (after `cargo bench --bench rack_scaling --`):
//!   --rows N          dataset rows (default 1<<14; dense/spmv workloads
//!                     cap at 4096 rows — printed when the cap applies)
//!   --shards a,b,c    shard-count sweep (default 1,2,4,8)
//!   --workers W       per-shard simulator backend threads (default 1)
//!   --verify          assert every sharded result bit-equal to the
//!                     single-device (1-shard-values) reference

use prins::algorithms::{
    dot_sharded, euclidean_sharded, histogram_sharded, spmv_sharded,
};
use prins::host::rack::PrinsRack;
use prins::metrics::bench::{
    arg_u64, shards_sweep_from_args, write_rack_json, RackRecord,
};
use prins::rcam::{DeviceModel, ExecBackend, InterconnectModel};
use prins::workloads::{synth_csr, synth_hist_samples, synth_samples, synth_uniform, Rng};
use std::time::Instant;

const DIMS: usize = 8;

fn rack(shards: usize, backend: ExecBackend) -> PrinsRack {
    PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    )
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows = arg_u64(&args, "--rows", 1 << 14) as usize;
    let sweep = shards_sweep_from_args(&args, &[1, 2, 4, 8]);
    let workers = arg_u64(&args, "--workers", 1) as usize;
    let backend = ExecBackend::from_workers(workers);
    let verify = args.iter().any(|a| a == "--verify");

    // the microcoded dense kernels and spmv simulate every pass over every
    // row; cap them so the sweep stays minutes-scale at large --rows
    let dense_rows = rows.min(4096);
    if dense_rows != rows {
        println!("note: dp/ed/spmv capped at {dense_rows} rows (hist uses {rows})");
    }
    println!("rows = {rows}, shard sweep = {sweep:?}, backend = {backend:?}");

    let xs = synth_hist_samples(rows, 7);
    let xv = synth_samples(dense_rows, DIMS, 4, 11);
    let h = synth_uniform(DIMS, 12);
    let centers = synth_uniform(DIMS, 13);
    let a = synth_csr(dense_rows, dense_rows * 8, 17);
    let mut rng = Rng::seed_from(18);
    let x: Vec<f32> = (0..dense_rows).map(|_| rng.f32_range(-1.0, 1.0)).collect();

    // single-device-value reference for --verify (a 1-shard rack computes
    // exactly the single-device result values). When the sweep itself
    // starts at shards=1 — the default, and what CI runs — the reference
    // is captured from that iteration instead of being computed twice.
    type Reference = (Vec<u64>, Vec<f32>, Vec<Vec<f32>>, Vec<f32>);
    let mut reference: Option<Reference> = None;
    if verify && sweep.first() != Some(&1) {
        let r1 = rack(1, backend);
        reference = Some((
            histogram_sharded(&r1, &xs).hist,
            dot_sharded(&r1, &xv, dense_rows, DIMS, &h).dp,
            euclidean_sharded(&r1, &xv, dense_rows, DIMS, &centers, 1, 5).dists,
            spmv_sharded(&r1, &a, &x).y,
        ));
    }

    let mut records: Vec<RackRecord> = Vec::new();
    let push = |records: &mut Vec<RackRecord>,
                    bench: &str,
                    nrows: usize,
                    shards: usize,
                    rs: &prins::host::rack::RackStats,
                    wall: f64| {
        println!(
            "{bench:<5} shards={shards:<2} total_cycles={:>9} max_shard={:>9} \
             link_bytes={:>9} energy={:.3e} J  wall={:.3}s",
            rs.total_cycles, rs.max_shard_cycles, rs.link_bytes, rs.energy_j, wall
        );
        records.push(RackRecord {
            bench: bench.into(),
            rows: nrows as u64,
            shards: shards as u64,
            total_cycles: rs.total_cycles,
            max_shard_cycles: rs.max_shard_cycles,
            link_bytes: rs.link_bytes,
            energy_j: rs.energy_j,
            wall_s: wall,
        });
    };

    for &s in &sweep {
        let rk = rack(s, backend);

        let t0 = Instant::now();
        let hist = histogram_sharded(&rk, &xs);
        push(&mut records, "hist", rows, s, &hist.rack, t0.elapsed().as_secs_f64());

        let t0 = Instant::now();
        let dp = dot_sharded(&rk, &xv, dense_rows, DIMS, &h);
        push(&mut records, "dp", dense_rows, s, &dp.rack, t0.elapsed().as_secs_f64());

        let t0 = Instant::now();
        let ed = euclidean_sharded(&rk, &xv, dense_rows, DIMS, &centers, 1, 5);
        push(&mut records, "ed", dense_rows, s, &ed.rack, t0.elapsed().as_secs_f64());

        let t0 = Instant::now();
        let sp = spmv_sharded(&rk, &a, &x);
        push(&mut records, "spmv", dense_rows, s, &sp.rack, t0.elapsed().as_secs_f64());

        if verify && s == 1 && reference.is_none() {
            reference = Some((
                hist.hist.clone(),
                dp.dp.clone(),
                ed.dists.clone(),
                sp.y.clone(),
            ));
            println!("captured shards=1 values as the verification reference");
        } else if let Some((rh, rd, re, ry)) = &reference {
            assert_eq!(&hist.hist, rh, "shards={s}: histogram mismatch");
            assert!(
                dp.dp.iter().zip(rd).all(|(a, b)| a.to_bits() == b.to_bits()),
                "shards={s}: dp mismatch"
            );
            for (c, (ec, rc)) in ed.dists.iter().zip(re).enumerate() {
                assert!(
                    ec.iter().zip(rc).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "shards={s}: ed center {c} mismatch"
                );
            }
            assert!(
                sp.y.iter().zip(ry).all(|(a, b)| a.to_bits() == b.to_bits()),
                "shards={s}: spmv mismatch"
            );
            println!("verified shards={s} bit-equal to single-device values");
        }
    }

    match write_rack_json("rack", &records) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_rack.json: {e}");
            std::process::exit(1);
        }
    }
}
