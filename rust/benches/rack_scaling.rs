//! Rack shard-count scaling bench (DESIGN.md §Sharding): run **every
//! registered kernel** (the registry currently carries hist / dp / ed /
//! spmv / search — a newly registered workload joins automatically) over
//! a shard-count sweep and write the modeled rack figures to
//! `BENCH_rack.json` at the repository root — the scaling curves the
//! README's "Run a rack" table is fed from.
//!
//! Flags (after `cargo bench --bench rack_scaling -- ...`):
//!   --rows N          dataset rows (default 1<<14; dense workloads cap
//!                     at 4096 rows — printed when the cap applies)
//!   --shards a,b,c    shard-count sweep (default 1,2,4,8)
//!   --workers W       per-shard simulator backend threads (default 1)
//!   --verify          assert every sharded result bit-equal to the
//!                     single-device (1-shard) reference

use prins::host::rack::PrinsRack;
use prins::metrics::bench::{
    arg_u64, rack_registry_points, shards_sweep_from_args, write_rack_json, RackRecord,
};
use prins::rcam::{DeviceModel, ExecBackend, InterconnectModel};
use std::collections::HashMap;

const DIMS: usize = 8;
const SEED: u64 = 17;
const DENSE_CAP: usize = 4096;

fn rack(shards: usize, backend: ExecBackend) -> PrinsRack {
    PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    )
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows = arg_u64(&args, "--rows", 1 << 14) as usize;
    let sweep = shards_sweep_from_args(&args, &[1, 2, 4, 8]);
    let workers = arg_u64(&args, "--workers", 1) as usize;
    let backend = ExecBackend::from_workers(workers);
    let verify = args.iter().any(|a| a == "--verify");

    if rows > DENSE_CAP {
        println!("note: dense kernels capped at {DENSE_CAP} rows (compare-only kernels use {rows})");
    }
    println!("rows = {rows}, shard sweep = {sweep:?}, backend = {backend:?}");

    // single-device-value reference for --verify (a 1-shard rack computes
    // exactly the single-device result values). When the sweep itself
    // starts at shards=1 — the default, and what CI runs — the reference
    // is captured from that iteration instead of being computed twice.
    let mut reference: HashMap<&'static str, Vec<u64>> = HashMap::new();
    if verify && sweep.first() != Some(&1) {
        for p in rack_registry_points(&rack(1, backend), rows, DENSE_CAP, DIMS, SEED) {
            reference.insert(p.name, p.bits);
        }
    }

    let mut records: Vec<RackRecord> = Vec::new();
    for &s in &sweep {
        let points = rack_registry_points(&rack(s, backend), rows, DENSE_CAP, DIMS, SEED);
        let mut captured = false;
        for p in points {
            if verify {
                if let Some(r) = reference.get(p.name) {
                    assert_eq!(
                        &p.bits, r,
                        "shards={s}: {} diverged from the single-device values",
                        p.name
                    );
                } else if s == 1 {
                    reference.insert(p.name, p.bits);
                    captured = true;
                }
            }
            records.push(p.record);
        }
        if verify && captured {
            println!("captured shards=1 values as the verification reference");
        } else if verify {
            println!("verified shards={s} bit-equal to single-device values");
        }
    }

    match write_rack_json("rack", &records) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_rack.json: {e}");
            std::process::exit(1);
        }
    }
}
