//! Resident-dataset amortization bench (DESIGN.md §Resident datasets):
//! load each workload onto a rack **once**, run Q queries with fresh
//! parameters per query (new bin edges / hyperplane / centers / x
//! vector), and write the amortization curve to `BENCH_resident.json` at
//! the repository root. Per-query modeled cycles collapse from
//! `load + query` at Q=1 toward the query floor as Q grows — the
//! storage-appliance claim (load once, serve many) in one JSON file.
//!
//! Flags (after `cargo bench --bench resident_queries --`):
//!   --rows N          histogram sample count (default 1<<14; the dense
//!                     microcoded workloads and spmv cap at 512 rows)
//!   --queries a,b,c   query-count sweep (default 1,4,16,64)
//!   --shards S        shard-device count of the resident rack (default 1)
//!   --workers W       per-shard simulator backend threads (default 1)
//!   --verify          assert the first and last query of each sweep
//!                     bit-equal to a freshly loaded run with the same
//!                     params (intermediate queries are covered by the
//!                     tier-1 suite `tests/resident_datasets.rs`, which
//!                     checks every query)

use prins::algorithms::{
    dot_sharded, euclidean_sharded, histogram_baseline_at, spmv_sharded, ResidentDot,
    ResidentEuclidean, ResidentHistogram, ResidentSpmv,
};
use prins::host::rack::PrinsRack;
use prins::metrics::bench::{
    arg_u64, queries_sweep_from_args, write_resident_json, ResidentRecord,
};
use prins::rcam::{DeviceModel, ExecBackend, InterconnectModel};
use prins::workloads::{synth_csr, synth_hist_samples, synth_samples, synth_uniform, Rng};
use std::time::Instant;

const DIMS: usize = 8;
const SEED: u64 = 7;

fn rack(shards: usize, backend: ExecBackend) -> PrinsRack {
    PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    )
}

/// Per-query parameter streams, deterministic in the query index.
fn hist_lo(q: usize) -> u16 {
    [24u16, 16, 8, 0][q % 4]
}

fn dp_h(q: usize) -> Vec<f32> {
    synth_uniform(DIMS, SEED + 100 + q as u64)
}

fn ed_centers(q: usize) -> Vec<f32> {
    synth_uniform(DIMS, SEED + 200 + q as u64)
}

fn spmv_x(n: usize, q: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from(SEED + 300 + q as u64);
    (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows = arg_u64(&args, "--rows", 1 << 14) as usize;
    let sweep = queries_sweep_from_args(&args, &[1, 4, 16, 64]);
    let shards = arg_u64(&args, "--shards", 1) as usize;
    let workers = arg_u64(&args, "--workers", 1) as usize;
    let backend = ExecBackend::from_workers(workers);
    let verify = args.iter().any(|a| a == "--verify");

    // the dense microcoded kernels and spmv simulate every pass over
    // every row per query; cap them so a 64-query sweep stays fast
    let dense_rows = rows.min(512);
    if dense_rows != rows {
        println!("note: dp/ed/spmv capped at {dense_rows} rows (hist uses {rows})");
    }
    println!("rows = {rows}, query sweep = {sweep:?}, shards = {shards}, backend = {backend:?}");

    let xs = synth_hist_samples(rows, SEED);
    let xv = synth_samples(dense_rows, DIMS, 4, SEED + 1);
    let a = synth_csr(dense_rows, dense_rows * 8, SEED + 2);

    let mut records: Vec<ResidentRecord> = Vec::new();
    let mut push = |bench: &str,
                    nrows: usize,
                    queries: usize,
                    load_cycles: u64,
                    qcycles: &[u64],
                    energy_j: f64,
                    wall: f64| {
        let qsum: u64 = qcycles.iter().sum();
        let query_cycles = qsum as f64 / queries as f64;
        let amortized = (load_cycles + qsum) as f64 / queries as f64;
        println!(
            "{bench:<5} Q={queries:<3} load={load_cycles:>9} query/Q={query_cycles:>12.1} \
             amortized/Q={amortized:>12.1} energy={energy_j:.3e} J  wall={wall:.3}s"
        );
        records.push(ResidentRecord {
            bench: bench.into(),
            rows: nrows as u64,
            shards: shards as u64,
            queries: queries as u64,
            load_cycles,
            query_cycles,
            amortized_cycles: amortized,
            energy_j,
            wall_s: wall,
        });
    };

    for &q_count in &sweep {
        assert!(q_count > 0, "--queries entries must be positive");
        let rk = rack(shards, backend);

        // ---- histogram: fresh bin edges per query -----------------------
        let t0 = Instant::now();
        let mut res = ResidentHistogram::load(&rk, &xs);
        let load_cycles = res.load_report().total_cycles;
        let mut energy = res.load_report().energy_j;
        let mut qcycles = Vec::with_capacity(q_count);
        for q in 0..q_count {
            let r = res.query_at(hist_lo(q));
            qcycles.push(r.rack.total_cycles);
            energy += r.rack.energy_j;
            if verify && (q == 0 || q == q_count - 1) {
                // fresh load + same bin window = the one-shot reference
                let fresh = ResidentHistogram::load(&rk, &xs).query_at(hist_lo(q));
                assert_eq!(
                    r.hist, fresh.hist,
                    "hist Q={q_count} q={q}: resident query diverged from fresh load"
                );
                assert_eq!(
                    r.hist,
                    histogram_baseline_at(&xs, hist_lo(q)),
                    "hist Q={q_count} q={q}: resident query diverged from baseline"
                );
            }
        }
        push("hist", rows, q_count, load_cycles, &qcycles, energy, t0.elapsed().as_secs_f64());

        // ---- dot product: fresh hyperplane per query --------------------
        let t0 = Instant::now();
        let mut res = ResidentDot::load(&rk, &xv, dense_rows, DIMS);
        let load_cycles = res.load_report().total_cycles;
        let mut energy = res.load_report().energy_j;
        let mut qcycles = Vec::with_capacity(q_count);
        for q in 0..q_count {
            let h = dp_h(q);
            let r = res.query(&h);
            qcycles.push(r.rack.total_cycles);
            energy += r.rack.energy_j;
            if verify && (q == 0 || q == q_count - 1) {
                let fresh = dot_sharded(&rk, &xv, dense_rows, DIMS, &h);
                assert!(
                    r.dp.iter().zip(&fresh.dp).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "dp Q={q_count} q={q}: resident query diverged from one-shot"
                );
            }
        }
        push("dp", dense_rows, q_count, load_cycles, &qcycles, energy, t0.elapsed().as_secs_f64());

        // ---- euclidean distance: fresh center per query -----------------
        let t0 = Instant::now();
        let mut res = ResidentEuclidean::load(&rk, &xv, dense_rows, DIMS);
        let load_cycles = res.load_report().total_cycles;
        let mut energy = res.load_report().energy_j;
        let mut qcycles = Vec::with_capacity(q_count);
        for q in 0..q_count {
            let c = ed_centers(q);
            let r = res.query(&c, 1, 5);
            qcycles.push(r.rack.total_cycles);
            energy += r.rack.energy_j;
            if verify && (q == 0 || q == q_count - 1) {
                let fresh = euclidean_sharded(&rk, &xv, dense_rows, DIMS, &c, 1, 5);
                assert!(
                    r.dists[0]
                        .iter()
                        .zip(&fresh.dists[0])
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "ed Q={q_count} q={q}: resident query diverged from one-shot"
                );
            }
        }
        push("ed", dense_rows, q_count, load_cycles, &qcycles, energy, t0.elapsed().as_secs_f64());

        // ---- spmv: fresh x vector per query -----------------------------
        let t0 = Instant::now();
        let mut res = ResidentSpmv::load(&rk, &a);
        let load_cycles = res.load_report().total_cycles;
        let mut energy = res.load_report().energy_j;
        let mut qcycles = Vec::with_capacity(q_count);
        for q in 0..q_count {
            let x = spmv_x(dense_rows, q);
            let r = res.query(&x);
            qcycles.push(r.rack.total_cycles);
            energy += r.rack.energy_j;
            if verify && (q == 0 || q == q_count - 1) {
                let fresh = spmv_sharded(&rk, &a, &x);
                assert!(
                    r.y.iter().zip(&fresh.y).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "spmv Q={q_count} q={q}: resident query diverged from one-shot"
                );
            }
        }
        push("spmv", dense_rows, q_count, load_cycles, &qcycles, energy, t0.elapsed().as_secs_f64());
    }

    if verify {
        println!(
            "verified: first/last resident queries of every sweep bit-equal to \
             freshly loaded runs"
        );
    }

    match write_resident_json("resident", &records) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_resident.json: {e}");
            std::process::exit(1);
        }
    }
}
