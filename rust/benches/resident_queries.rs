//! Resident-dataset amortization bench (DESIGN.md §Resident datasets):
//! load **every registered kernel**'s workload onto a rack once (the
//! registry currently carries hist / dp / ed / spmv / search — a newly
//! registered workload joins automatically), run Q queries with fresh
//! parameters per query (the kernel's seeded parameter stream: new bin
//! edges / hyperplane / centers / x vector / search range), and write
//! the amortization curve to `BENCH_resident.json` at the repository
//! root. Per-query modeled cycles collapse from `load + query` at Q=1
//! toward the query floor as Q grows — the storage-appliance claim
//! (load once, serve many) in one JSON file.
//!
//! Flags (after `cargo bench --bench resident_queries -- ...`):
//!   --rows N          dataset rows (default 1<<14; dense workloads cap
//!                     at 512 rows — printed when the cap applies)
//!   --queries a,b,c   query-count sweep (default 1,4,16,64)
//!   --batch a,b,c     operands packed per query sweep (default 1,2,4;
//!                     batch > 1 covers only the kernels with a batched
//!                     parameter stream — search and ed — and the JSON
//!                     gains per-operand cycles vs the unbatched floor)
//!   --shards S        shard-device count of the resident rack (default 1)
//!   --workers W       per-shard simulator backend threads (default 1)
//!   --verify          assert the first and last query of each sweep
//!                     bit-equal to a freshly loaded run with the same
//!                     params (intermediate queries are covered by the
//!                     tier-1 suite `tests/resident_datasets.rs`, which
//!                     checks every query)

use prins::host::rack::PrinsRack;
use prins::metrics::bench::{
    arg_u64, batch_sweep_from_args, queries_sweep_from_args, resident_registry_points,
    write_resident_json, ResidentRecord,
};
use prins::rcam::{DeviceModel, ExecBackend, InterconnectModel};

const DIMS: usize = 8;
const SEED: u64 = 7;
const DENSE_CAP: usize = 512;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows = arg_u64(&args, "--rows", 1 << 14) as usize;
    let sweep = queries_sweep_from_args(&args, &[1, 4, 16, 64]);
    let batches = batch_sweep_from_args(&args, &[1, 2, 4]);
    let shards = arg_u64(&args, "--shards", 1) as usize;
    let workers = arg_u64(&args, "--workers", 1) as usize;
    let backend = ExecBackend::from_workers(workers);
    let verify = args.iter().any(|a| a == "--verify");

    // the dense microcoded kernels simulate every pass over every row
    // per query; cap them so a 64-query sweep stays fast
    if rows > DENSE_CAP {
        println!("note: dense kernels capped at {DENSE_CAP} rows (compare-only kernels use {rows})");
    }
    println!(
        "rows = {rows}, query sweep = {sweep:?}, batch sweep = {batches:?}, \
         shards = {shards}, backend = {backend:?}"
    );

    let rack = PrinsRack::with_config(
        shards,
        DeviceModel::default(),
        backend,
        InterconnectModel::default(),
    );
    let mut records: Vec<ResidentRecord> = Vec::new();
    for &batch in &batches {
        for &q_count in &sweep {
            records.extend(resident_registry_points(
                &rack, rows, DENSE_CAP, DIMS, q_count, batch, SEED, verify,
            ));
        }
    }

    if verify {
        println!(
            "verified: first/last resident queries of every sweep bit-equal to \
             freshly loaded runs"
        );
    }

    match write_resident_json("resident", &records) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_resident.json: {e}");
            std::process::exit(1);
        }
    }
}
