//! Regenerates paper Fig. 14: BFS performance normalized to the 2.5 GTEPS
//! (10 GB/s) and 6 GTEPS (24 GB/s) references, over the Table 3 graphs
//! ordered by average out-degree. Reports BOTH the literal Algorithm 5
//! measurement and the paper's vertex-serial model (see EXPERIMENTS.md).
use prins::model::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let t = figures::fig14(1 << 11);
    println!("{}", t.render());
    println!("paper shape (model columns): speedup ordered by avg out-degree,");
    println!("up to ~7x for hollywood-09; the literal edge-serial Algorithm 5");
    println!("is far slower — see EXPERIMENTS.md for the discrepancy analysis.");
    println!("(simulated in {:?})", t0.elapsed());
}
