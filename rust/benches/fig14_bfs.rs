//! Regenerates paper Fig. 14: BFS performance normalized to the 2.5 GTEPS
//! (10 GB/s) and 6 GTEPS (24 GB/s) references, over the Table 3 graphs
//! ordered by average out-degree. Reports BOTH the literal Algorithm 5
//! measurement and the paper's vertex-serial model (see EXPERIMENTS.md).
//! Run: `cargo bench --bench fig14_bfs` (`-- --workers N` selects the
//! simulator backend; results are backend-invariant).
use prins::metrics::bench::{backend_from_args, write_bench_json, BenchRecord};
use prins::model::figures;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend = backend_from_args(&args);
    let sim_v = 1usize << 11;
    let t0 = std::time::Instant::now();
    let t = figures::fig14_on(sim_v, backend);
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", t.render());
    println!("paper shape (model columns): speedup ordered by avg out-degree,");
    println!("up to ~7x for hollywood-09; the literal edge-serial Algorithm 5");
    println!("is far slower — see EXPERIMENTS.md for the discrepancy analysis.");
    println!("(simulated in {wall:.3}s, backend {backend:?})");
    let rec = BenchRecord {
        bench: "fig14".into(),
        rows: sim_v as u64,
        workers: backend.workers() as u64,
        ops_per_s: sim_v as f64 / wall,
        wall_s: wall,
    };
    if let Ok(p) = write_bench_json("fig14", &[rec]) {
        println!("wrote {}", p.display());
    }
}
